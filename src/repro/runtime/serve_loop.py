"""Serving step builders: batched prefill + decode with the paper's HABF
integrated as a first-class admission/blocklist gate (DESIGN.md §2).

  * prefill: optional HABF *admission probe* — the two-round query (pure
    jnp form, lowers on any backend; the Pallas kernel is the TPU runtime
    path) over the batch's prefix fingerprints against the pod-local
    KV-prefix-cache index.  A hit means the prefix KV is resident; a false
    positive costs a wasted cache probe + re-prefill — the weighted-FPR
    cost the paper minimizes.
  * decode: optional fused n-gram blocklist probe on the trailing window
    of emitted tokens.

Both gates are pure functions of replicated filter tables (a few MB,
VMEM-resident on TPU) and add no cross-device communication.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.habf_query.ref import habf_query_ref
from ..kernels.ngram_blocklist.ref import ngram_fingerprints
from ..kernels.common import probe_bits, hash_value, fastrange
from ..models.model import Model


def habf_gate_tables(habf) -> dict:
    """Replicated device arrays for the fused admission probe."""
    from ..kernels.habf_query.ops import device_tables
    return device_tables(habf)


def admission_probe(tables: dict, prefix_lo, prefix_hi):
    return habf_query_ref(
        prefix_lo, prefix_hi, tables["words"],
        tables["hx_hashidx"].astype(jnp.int32),
        tables["hx_endbit"].astype(jnp.int32),
        tables["c1"], tables["c2"], tables["mul"],
        tables["f_consts"][0], tables["f_consts"][1], tables["f_consts"][2],
        tables["h0_idx"], m=tables["m"], omega=tables["omega"],
        k=tables["k"], double_hash=tables["double_hash"])


def make_prefill_step(model: Model, habf_tables: dict | None = None):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        out = {"next_token": jnp.argmax(logits, axis=-1).astype(jnp.int32)}
        if habf_tables is not None:
            out["admit"] = admission_probe(habf_tables, batch["prefix_lo"],
                                           batch["prefix_hi"])
        return out, cache

    return prefill_step


def make_decode_step(model: Model, blocklist: dict | None = None,
                     ngram_n: int = 4):
    """decode_step(params, tokens, cache, pos[, last_window]) -> out, cache.
    last_window: (B, ngram_n) trailing tokens incl. the new one, for the
    fused blocklist probe."""

    def decode_step(params, tokens, cache, pos, last_window=None):
        logits, cache = model.decode(params, tokens, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = {"next_token": nxt}
        if blocklist is not None and last_window is not None:
            win = jnp.concatenate([last_window[:, 1:], nxt[:, None]], axis=1)
            lo, hi = ngram_fingerprints(win, win.shape[1])
            acc = jnp.ones(lo[:, -1].shape, jnp.uint32)
            for j in range(blocklist["k"]):
                hv = hash_value(lo[:, -1], hi[:, -1], blocklist["c1"][j],
                                blocklist["c2"][j], blocklist["mul"][j])
                acc = acc & probe_bits(blocklist["words"],
                                       fastrange(hv, blocklist["m"]))
            out["blocked"] = acc.astype(jnp.bool_)
            out["window"] = win
        return out, cache

    return decode_step


def blocklist_tables(bf) -> dict:
    t = bf.device_tables()
    idx = t["hash_idx"]
    return {"words": jnp.asarray(t["words"]), "m": t["m"], "k": len(idx),
            "c1": jnp.asarray(t["c1"][idx]), "c2": jnp.asarray(t["c2"][idx]),
            "mul": jnp.asarray(t["mul"][idx])}


def generate(model: Model, params, prompt_batch: dict, cache, steps: int,
             decode_step=None, pos0: int | None = None):
    """Greedy generation driver (host loop; each step jit-compiled once)."""
    decode_step = decode_step or make_decode_step(model)
    prefill = jax.jit(make_prefill_step(model))
    out, cache = prefill(params, prompt_batch, cache)
    tok = out["next_token"]
    T = prompt_batch["tokens"].shape[1]
    if pos0 is None:
        pos0 = T + (model.cfg.n_img_tokens if model.cfg.family == "vlm" else 0)
    dstep = jax.jit(decode_step)
    toks = [tok]
    for i in range(steps - 1):
        out, cache = dstep(params, tok, cache, jnp.int32(pos0 + i))
        tok = out["next_token"]
        toks.append(tok)
    return jnp.stack(toks, axis=1), cache
