"""Serving step builders: batched prefill + decode with the paper's HABF
integrated as a first-class admission/blocklist gate (DESIGN.md §2).

  * prefill: optional *admission probe* — a traceable membership query
    (pure jnp form, lowers on any backend; the Pallas kernel is the TPU
    runtime path) over the batch's prefix fingerprints against the
    pod-local KV-prefix-cache index.  A hit means the prefix KV is
    resident; a false positive costs a wasted cache probe + re-prefill —
    the weighted-FPR cost the paper minimizes.  Any table-backed artifact
    serves (HABF/Bloom/Xor/WBF — see `kernels.dispatch.artifact_ref`).
  * decode: optional fused n-gram blocklist probe on the trailing window
    of emitted tokens.

Both gates take typed pytree artifacts (see repro.kernels.artifacts):
a few MB of replicated, VMEM-resident filter tables that close over into
the jitted steps — and, being pytrees, can be `jax.device_put` with a
sharding, donated, or hot-swapped from an npz.  A `FilterBank`
(repro.runtime.filter_bank) serves both gates as two named entries with
placement + telemetry; `generate(..., bank=bank)` routes through it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.artifacts import NgramArtifact
from ..kernels.dispatch import artifact_ref
from ..kernels.ngram_blocklist.ref import ngram_fingerprints
from ..kernels.common import probe_bits, hash_value, fastrange
from ..models.model import Model


def admission_probe(gate, prefix_lo, prefix_hi):
    """Traceable admission probe; usable inside jitted steps.  Accepts any
    table-backed artifact (HABF/Bloom/Xor/WBF), not just HABF."""
    return artifact_ref(gate, prefix_lo, prefix_hi)


def make_prefill_step(model: Model, admission=None):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        out = {"next_token": jnp.argmax(logits, axis=-1).astype(jnp.int32)}
        if admission is not None:
            out["admit"] = admission_probe(admission, batch["prefix_lo"],
                                           batch["prefix_hi"])
        return out, cache

    return prefill_step


def blocklist_probe(blocklist: NgramArtifact, window):
    """Traceable probe of one (B, n) token window against the blocklist
    (the fused decode-gate body, shared with the boundary probe)."""
    lo, hi = ngram_fingerprints(window, blocklist.n)
    acc = jnp.ones(lo[:, -1].shape, jnp.uint32)
    for j in range(blocklist.k):
        hv = hash_value(lo[:, -1], hi[:, -1], blocklist.c1[j],
                        blocklist.c2[j], blocklist.mul[j])
        acc = acc & probe_bits(blocklist.words, fastrange(hv, blocklist.m))
    return acc.astype(jnp.bool_)


def make_decode_step(model: Model, blocklist: NgramArtifact | None = None):
    """decode_step(params, tokens, cache, pos[, last_window, window_fill])
    -> out, cache.

    Window contract: ``last_window`` is the (B, n) trailing token window
    ending at ``tokens`` — the *previous* step's emission — NOT including
    this step's new token.  The step shifts it left and appends the token
    it just generated, so the probed window ends at the new token; the
    updated window comes back as ``out["window"]`` for the next step.

    ``window_fill`` (scalar or per-row (B,) int32, optional) counts how
    many trailing entries of ``last_window`` are real tokens.  When
    given, the probe is
    masked until the shifted window holds n real tokens, so left-padding
    (token id 0) can never spuriously match blocklist entries containing
    token 0; the updated count comes back as ``out["window_fill"]``.
    Callers that seed the window from the prompt tail (see
    ``seed_window``) start full and pay no masked steps."""

    def decode_step(params, tokens, cache, pos, last_window=None,
                    window_fill=None):
        logits, cache = model.decode(params, tokens, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = {"next_token": nxt}
        if blocklist is not None and last_window is not None:
            win = jnp.concatenate([last_window[:, 1:], nxt[:, None]], axis=1)
            blocked = blocklist_probe(blocklist, win)
            if window_fill is not None:
                filled = jnp.minimum(window_fill + 1, blocklist.n)
                blocked = blocked & (filled >= blocklist.n)
                out["window_fill"] = filled
            out["blocked"] = blocked
            out["window"] = win
        return out, cache

    # generate() reads this to coordinate window threading with a
    # caller-supplied step (the gate is baked into the closure)
    decode_step.blocklist = blocklist
    return decode_step


def seed_window(prompt_tokens, first_token, n: int, prompt_lens=None):
    """Initial (last_window, window_fill) for the decode loop: the window
    ends at the prefill's first emitted token, preceded by the trailing
    n-1 prompt tokens (so n-grams spanning the prompt/generation boundary
    are caught), left-padded with zeros when the prompt is shorter.

    ``prompt_lens`` (B,) gives the number of *real* trailing tokens per
    row for ragged, left-padded prompt batches; the returned fill is then
    per-row, so padded rows stay probe-masked until their window holds n
    real tokens.  Without it every prompt token counts as real."""
    prompt_tokens = jnp.asarray(prompt_tokens, jnp.int32)
    T = prompt_tokens.shape[1]
    tail = prompt_tokens[:, T - min(T, n - 1):]
    pad = n - 1 - tail.shape[1]
    if pad:
        tail = jnp.pad(tail, ((0, 0), (pad, 0)))
    win = jnp.concatenate([tail, first_token[:, None].astype(jnp.int32)],
                          axis=1)
    if prompt_lens is None:
        return win, jnp.int32(min(T, n - 1) + 1)
    fill = jnp.minimum(jnp.asarray(prompt_lens, jnp.int32), n - 1) + 1
    return win, fill


def _resolve_gate(bank, explicit, default_name: str):
    """Gate resolution for `generate` -> (artifact | None, bank entry name
    | None): an artifact wins outright; a string names a bank entry; with
    just a bank, the conventional entry name is used when present.  The
    resolved name is what telemetry outcomes are accounted to."""
    if explicit is not None and not isinstance(explicit, str):
        return explicit, None
    if bank is None:
        if isinstance(explicit, str):
            raise ValueError(f"gate {explicit!r} named by string but no "
                             "FilterBank was given")
        return None, None
    if isinstance(explicit, str):
        return bank.artifact(explicit), explicit    # KeyError if missing
    if default_name in bank:
        return bank.artifact(default_name), default_name
    return None, None


def generate(model: Model, params, prompt_batch: dict, cache, steps: int,
             *, bank=None, admission=None, blocklist=None, decode_step=None,
             pos0: int | None = None, prompt_lens=None):
    """Greedy generation driver (host loop; each step jit-compiled once).

    Gates: pass artifacts directly (``admission=``, ``blocklist=``) or a
    `FilterBank` (entries named "admission" / "blocklist" by convention;
    pass a string to pick a different entry).  Both gates are live in the
    loop: the prefill step probes the admission filter and the decode
    steps thread the trailing token window (seeded from the prompt tail)
    through the fused blocklist probe.  For ragged, left-padded prompt
    batches pass ``prompt_lens`` (B,) so padded rows stay probe-masked
    (see ``seed_window``).  A caller-supplied ``decode_step`` must carry
    the same blocklist (build it with `make_decode_step`).

    Returns ``(tokens (B, steps), cache, report)`` where report carries
    per-request gate outcomes: ``admit`` (B,) bool, ``blocked``
    (B, steps) bool — column i flags the n-gram ending at tokens[:, i],
    so the boundary gram ending at the prefill's first emission is probed
    too — and ``blocked_ngrams`` total.  Gate outcomes are accounted into
    the bank entry they resolved from when a bank is given.
    """
    adm, adm_name = _resolve_gate(bank, admission, "admission")
    bl, bl_name = _resolve_gate(bank, blocklist, "blocklist")
    if decode_step is None:
        decode_step = make_decode_step(model, blocklist=bl)
    else:
        # coordinate with the gate baked into a caller-supplied step: a
        # step built with its own blocklist keeps its gate live (the
        # window is threaded for it); a gateless step cannot serve a
        # resolved blocklist — fail loudly instead of probing nothing
        step_bl = getattr(decode_step, "blocklist", None)
        if step_bl is not None:
            if bl is not None and step_bl is not bl:
                raise ValueError(
                    "decode_step was built with a different blocklist "
                    "artifact than the one resolved from bank/blocklist=")
            if bl is None:
                bl, bl_name = step_bl, None
        elif bl is not None:
            raise ValueError(
                "a blocklist gate was resolved but decode_step was built "
                "without one; build it with make_decode_step(model, "
                "blocklist=...) or drop the decode_step argument")
    prefill = jax.jit(make_prefill_step(model, admission=adm))
    out, cache = prefill(params, prompt_batch, cache)
    tok = out["next_token"]
    report: dict = {}
    if "admit" in out:
        report["admit"] = np.asarray(out["admit"])
    T = prompt_batch["tokens"].shape[1]
    if pos0 is None:
        pos0 = T + (model.cfg.n_img_tokens if model.cfg.family == "vlm" else 0)
    window = fill = None
    blocked_cols = []
    if bl is not None:
        window, fill = seed_window(prompt_batch["tokens"], tok, bl.n,
                                   prompt_lens=prompt_lens)
        # the seeded window already ends at a generated token: probe it so
        # boundary-spanning n-grams ending at the first emission are caught
        blocked_cols.append(blocklist_probe(bl, window)
                            & (fill >= bl.n))
    dstep = jax.jit(decode_step)
    toks = [tok]
    for i in range(steps - 1):
        if window is not None:
            out, cache = dstep(params, tok, cache, jnp.int32(pos0 + i),
                               window, fill)
            window, fill = out["window"], out["window_fill"]
            blocked_cols.append(out["blocked"])
        else:
            out, cache = dstep(params, tok, cache, jnp.int32(pos0 + i))
        tok = out["next_token"]
        toks.append(tok)
    if bl is not None:
        # single device->host transfer after the loop (no per-step sync)
        report["blocked"] = np.asarray(jnp.stack(blocked_cols, axis=1))
        report["blocked_ngrams"] = int(report["blocked"].sum())
    if bank is not None:
        if "admit" in report and adm_name is not None:
            bank.observe(adm_name, report["admit"])
        if "blocked" in report and bl_name is not None:
            bank.observe(bl_name, report["blocked"])
    return jnp.stack(toks, axis=1), cache, report
