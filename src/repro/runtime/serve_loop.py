"""Serving step builders: batched prefill + decode with the paper's HABF
integrated as a first-class admission/blocklist gate (DESIGN.md §2).

  * prefill: optional HABF *admission probe* — the two-round query (pure
    jnp form, lowers on any backend; the Pallas kernel is the TPU runtime
    path) over the batch's prefix fingerprints against the pod-local
    KV-prefix-cache index.  A hit means the prefix KV is resident; a false
    positive costs a wasted cache probe + re-prefill — the weighted-FPR
    cost the paper minimizes.
  * decode: optional fused n-gram blocklist probe on the trailing window
    of emitted tokens.

Both gates take typed pytree artifacts (`HABFArtifact` / `NgramArtifact`,
see repro.kernels.artifacts): a few MB of replicated, VMEM-resident filter
tables that close over into the jitted steps — and, being pytrees, can be
`jax.device_put` with a sharding, donated, or hot-swapped from an npz.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.artifacts import HABFArtifact, NgramArtifact
from ..kernels.dispatch import habf_artifact_ref
from ..kernels.ngram_blocklist.ref import ngram_fingerprints
from ..kernels.common import probe_bits, hash_value, fastrange
from ..models.model import Model


def admission_probe(gate: HABFArtifact, prefix_lo, prefix_hi):
    """Traceable two-round HABF probe; usable inside jitted steps."""
    return habf_artifact_ref(gate, prefix_lo, prefix_hi)


def make_prefill_step(model: Model, admission: HABFArtifact | None = None):
    def prefill_step(params, batch, cache):
        logits, cache = model.prefill(params, batch, cache)
        out = {"next_token": jnp.argmax(logits, axis=-1).astype(jnp.int32)}
        if admission is not None:
            out["admit"] = admission_probe(admission, batch["prefix_lo"],
                                           batch["prefix_hi"])
        return out, cache

    return prefill_step


def make_decode_step(model: Model, blocklist: NgramArtifact | None = None):
    """decode_step(params, tokens, cache, pos[, last_window]) -> out, cache.
    last_window: (B, blocklist.n) trailing tokens incl. the new one, for
    the fused blocklist probe."""

    def decode_step(params, tokens, cache, pos, last_window=None):
        logits, cache = model.decode(params, tokens, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = {"next_token": nxt}
        if blocklist is not None and last_window is not None:
            win = jnp.concatenate([last_window[:, 1:], nxt[:, None]], axis=1)
            lo, hi = ngram_fingerprints(win, blocklist.n)
            acc = jnp.ones(lo[:, -1].shape, jnp.uint32)
            for j in range(blocklist.k):
                hv = hash_value(lo[:, -1], hi[:, -1], blocklist.c1[j],
                                blocklist.c2[j], blocklist.mul[j])
                acc = acc & probe_bits(blocklist.words,
                                       fastrange(hv, blocklist.m))
            out["blocked"] = acc.astype(jnp.bool_)
            out["window"] = win
        return out, cache

    return decode_step


def generate(model: Model, params, prompt_batch: dict, cache, steps: int,
             decode_step=None, pos0: int | None = None):
    """Greedy generation driver (host loop; each step jit-compiled once)."""
    decode_step = decode_step or make_decode_step(model)
    prefill = jax.jit(make_prefill_step(model))
    out, cache = prefill(params, prompt_batch, cache)
    tok = out["next_token"]
    T = prompt_batch["tokens"].shape[1]
    if pos0 is None:
        pos0 = T + (model.cfg.n_img_tokens if model.cfg.family == "vlm" else 0)
    dstep = jax.jit(decode_step)
    toks = [tok]
    for i in range(steps - 1):
        out, cache = dstep(params, tok, cache, jnp.int32(pos0 + i))
        tok = out["next_token"]
        toks.append(tok)
    return jnp.stack(toks, axis=1), cache
