"""Distributed training step builder.

Features (all exercised by the dry-run + integration tests):
  * gradient accumulation: global batch split into `accum` sequential
    microbatches via lax.scan (bounds live activations for the 400B-class
    train_4k cells);
  * ZeRO-1 optimizer-state sharding: m/v (and Adafactor rows) additionally
    sharded over the data axis — XLA inserts the reduce-scatter/all-gather;
  * mixed precision: params in cfg.param_dtype, optimizer state in
    cfg.opt_state_dtype, loss/grads accumulated fp32;
  * logical-axis shardings resolved against the active mesh.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.model import Model
from ..optimizer.adamw import AdamW, Adafactor, AdamWState, global_norm
from . import sharding as sh


def make_train_step(model: Model, opt, accum: int = 1, accum_dtype=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch leaves are (B, ...); accum splits B.  accum_dtype
    (default fp32) can be bf16 for the 400B-class memory budget — the
    accumulator then costs 2 bytes/param instead of 4."""
    adt = accum_dtype or jnp.float32

    def train_step(params, opt_state, batch):
        if accum > 1:
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)

            def body(carry, mb):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(model.loss)(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(adt), gsum, grads)
                return (gsum, lsum + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: (g / accum).astype(jnp.float32),
                                 gsum)
            loss = lsum / accum
        else:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": global_norm(grads)}
        return new_params, new_state, metrics

    return train_step


def make_optimizer(cfg, lr=3e-4, total_steps=10_000, kind="adamw"):
    from ..optimizer.adamw import warmup_cosine
    sched = warmup_cosine(lr, warmup=min(200, total_steps // 10),
                          total=total_steps)
    if kind == "adafactor":
        return Adafactor(lr=sched)
    return AdamW(lr=sched, weight_decay=0.1,
                 state_dtype=jnp.dtype(cfg.opt_state_dtype))


# ---------------------------------------------------------------------------
# sharding resolution
# ---------------------------------------------------------------------------

def param_shardings(mesh: Mesh, spec_tree, rules=None, shapes=None):
    return sh.tree_shardings(mesh, spec_tree, rules, shapes=shapes)


def _zero1_one(mesh: Mesh, ns: NamedSharding, shape) -> NamedSharding:
    """Extend a param sharding with 'data' on the first free, divisible dim
    (ZeRO-1 placement for the matching optimizer-state leaf)."""
    spec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
    used = set()
    for part in spec:
        if part is None:
            continue
        used.update(part if isinstance(part, tuple) else (part,))
    if "data" in used or "data" not in mesh.axis_names:
        return ns
    n_data = mesh.shape["data"]
    for i, part in enumerate(spec):
        if part is None and shape[i] % n_data == 0 and shape[i] >= n_data:
            spec[i] = "data"
            return NamedSharding(mesh, P(*spec))
    return ns


def fsdp_shardings(mesh: Mesh, p_sh, params_shapes):
    """ZeRO-3 / FSDP: extend every param sharding with the data axis on its
    first free divisible dim (params re-gathered per layer at use)."""
    return jax.tree.map(lambda ns, p: _zero1_one(mesh, ns, p.shape),
                        p_sh, params_shapes)


def opt_state_shardings(mesh: Mesh, opt, params_shapes, pspecs,
                        zero1: bool = True, rules=None, p_sh=None):
    """Shardings for the optimizer-state pytree (AdamW or Adafactor)."""
    if p_sh is None:
        p_sh = sh.tree_shardings(mesh, pspecs, rules, shapes=params_shapes)
    scalar = NamedSharding(mesh, P())

    def moment(ns, shape):
        return _zero1_one(mesh, ns, shape.shape) if zero1 else ns

    if isinstance(opt, AdamW):
        m = jax.tree.map(moment, p_sh, params_shapes)
        return AdamWState(step=scalar, m=m, v=m)
    if isinstance(opt, Adafactor):
        def row(ns, shp):
            spec = list(ns.spec)[:-1] if shp.ndim >= 2 else list(ns.spec)
            return NamedSharding(mesh, P(*spec))

        def col(ns, shp):
            if shp.ndim >= 2:
                spec = list(ns.spec) + [None] * (shp.ndim - len(ns.spec))
                return NamedSharding(mesh, P(*(spec[:-2] + [spec[-1]])))
            return scalar

        vr = jax.tree.map(row, p_sh, params_shapes)
        vc = jax.tree.map(col, p_sh, params_shapes)
        from ..optimizer.adamw import AdafactorState
        return AdafactorState(step=scalar, vr=vr, vc=vc)
    raise TypeError(opt)


def batch_shardings(mesh: Mesh, batch_specs: dict, rules=None):
    rules = dict(sh.DEFAULT_RULES if rules is None else rules)

    def leaf(s):
        nd = len(s.shape)
        axes = ["batch"] + [None] * (nd - 1)
        return sh.spec_for(mesh, rules, axes, shape=s.shape)

    return jax.tree.map(leaf, batch_specs)


def metrics_shardings(mesh: Mesh):
    return {"loss": NamedSharding(mesh, P()),
            "grad_norm": NamedSharding(mesh, P())}
