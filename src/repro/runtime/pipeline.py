"""Pipeline parallelism: GPipe-style stage pipeline over a mesh axis.

Stage weights live sharded over the `pipe` axis (stage s on pipe rank s);
microbatches flow rank→rank via collective_permute inside shard_map.  The
schedule is the classic n_micro + n_stages - 1 step fill/drain; bubbles
are idle (masked) stage applications, so wall-clock efficiency is
n_micro / (n_micro + S - 1) — pick n_micro >> S.

Used as an *alternative* multi-pod layout (the default dry-run mesh uses
`pod` as extra DP; `make_pipeline_mesh` repurposes it as `pipe`).
Correctness vs sequential execution is tested on a host mesh in
tests/test_runtime_distributed.py.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe(apply_stage: Callable, params_stacked, x_micro, mesh: Mesh,
          axis: str = "pipe"):
    """apply_stage(stage_params, h) -> h, same shape.
    params_stacked: pytree, leaves (n_stages, ...) — sharded over `axis`.
    x_micro: (n_micro, mb, ...) microbatched inputs (replicated).
    Returns (n_micro, mb, ...) outputs of the final stage (replicated)."""
    S = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    n_steps = n_micro + S - 1

    def body(params_local, xm):
        idx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda t: t[0], params_local)   # my stage
        h0 = jnp.zeros_like(xm[0])
        outs0 = jnp.zeros_like(xm)

        def step(carry, t):
            h_in, outs = carry
            x_t = xm[jnp.clip(t, 0, n_micro - 1)]
            h_cur = jnp.where(idx == 0,
                              jnp.where(t < n_micro, x_t, jnp.zeros_like(x_t)),
                              h_in)
            active = (t >= idx) & (t - idx < n_micro)
            h_out = apply_stage(p, h_cur)
            h_out = jnp.where(active, h_out, jnp.zeros_like(h_out))
            oidx = jnp.clip(t - (S - 1), 0, n_micro - 1)
            write = (idx == S - 1) & (t >= S - 1)
            outs = jnp.where(write, outs.at[oidx].set(h_out), outs)
            h_next = jax.lax.ppermute(
                h_out, axis, [(i, i + 1) for i in range(S - 1)])
            return (h_next, outs), None

        (h, outs), _ = jax.lax.scan(step, (h0, outs0), jnp.arange(n_steps))
        # replicate final-stage outputs to every rank
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), params_stacked)
    return shard_map(body, mesh=mesh, in_specs=(pspec, P()),
                     out_specs=P(), check_rep=False)(params_stacked, x_micro)


def make_pipeline_mesh(n_stages: int = 2, data: int = 16, model: int = 8):
    """Repurpose the pod axis as `pipe` (multi-pod PP layout)."""
    return jax.make_mesh((n_stages, data, model), ("pipe", "data", "model"))
