"""Gradient compression for the data-parallel reduction.

Error-feedback int8 quantization (1-bit-Adam/PowerSGD family, simplest
sound member): each DP worker adds its residual, quantizes to int8 with a
*shared* scale (one scalar psum to agree on max|g|), reduces the int8
payload (sums of 256 int8 fit int32), dequantizes, and keeps the
quantization error as next step's residual.  Link traffic: 1 byte/grad
element + 2 scalars vs 4 bytes — a 4x collective-term reduction on the
data axis.

Implemented with shard_map so the reduction is explicit (GSPMD's implicit
all-reduce can't be intercepted).  Model-parallel reductions inside the
step remain uncompressed — this wraps the DP boundary only, which is
where the multi-pod collective term lives (pod axis traffic crosses DCN).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def ef_quantize_reduce(grads, error, axis_names=("data",)):
    """Inside-shard_map body: error-feedback int8 all-reduce (mean).
    grads/error: local pytrees.  Returns (reduced_grads, new_error)."""
    # jax.lax.axis_size was removed; psum of 1 over the axis is the
    # supported way to read a mapped axis' size inside shard_map
    n = 1
    for ax in axis_names:
        n *= jax.lax.psum(1, ax)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(g32))
        for ax in axis_names:
            amax = jax.lax.pmax(amax, ax)           # shared scale (scalar)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_e = g32 - deq
        total = q.astype(jnp.int32)
        for ax in axis_names:
            total = jax.lax.psum(total, ax)         # int8-wire payload
        return (total.astype(jnp.float32) * scale / n), new_e

    out = jax.tree.map(one, grads, error)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return red, err


def make_compressed_train_step(model, opt, mesh: Mesh,
                               axis_names=("data",)):
    """DP-explicit train step: per-shard grads -> compressed all-reduce ->
    replicated update.  Params replicated across `axis_names`; batch
    sharded on its leading dim.  For DP(xTP) meshes, wrap only the data
    axis; TP handled by inner sharding constraints as usual."""

    def local_step(params, opt_state, error, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, error = ef_quantize_reduce(grads, error, axis_names)
        for ax in axis_names:
            loss = jax.lax.pmean(loss, ax)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, error, {"loss": loss}

    replicated = P()
    batch_spec = P(axis_names if len(axis_names) > 1 else axis_names[0])
    pspec = jax.tree.map(lambda _: replicated, object())  # placeholder

    def step(params, opt_state, error, batch):
        rep = lambda tree: jax.tree.map(lambda _: replicated, tree)
        return shard_map(
            local_step, mesh=mesh,
            in_specs=(rep(params), rep(opt_state), rep(error),
                      jax.tree.map(lambda _: batch_spec, batch)),
            out_specs=(rep(params), rep(opt_state), rep(error),
                       {"loss": replicated}),
            check_rep=False,
        )(params, opt_state, error, batch)

    return step


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
