"""Fault tolerance: checkpoint/restart supervision, straggler detection,
elastic mesh rescaling.

On a real fleet the failure signal is a dead host / NCCL-ICI timeout; in
this container failures are injected (tests) or arrive as exceptions from
the step function.  The supervisor contract:

  * every step runs under a watchdog that records durations; steps slower
    than `straggler_factor` x running median raise a StragglerEvent entry
    (on TPU fleets the mitigation is re-sharding around the slow host or
    pre-emptive checkpoint — we record + optionally checkpoint);
  * on failure: restore latest checkpoint (params+opt+data state), rebuild
    the step, continue; bounded by max_restarts;
  * elastic restore: if the device count changed between runs, shardings
    are re-resolved against the new mesh (logical rules are mesh-agnostic)
    and leaves re-placed — see tests/test_checkpoint.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from statistics import median
from typing import Any, Callable

from ..checkpoint.checkpointer import Checkpointer


class InjectedFailure(RuntimeError):
    """Test hook standing in for a dead host / ICI timeout."""


@dataclass
class StragglerPolicy:
    factor: float = 3.0          # step > factor * median => straggler
    window: int = 32
    checkpoint_on_straggler: bool = False


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: list = field(default_factory=list)
    failures: list = field(default_factory=list)


class TrainSupervisor:
    """Runs a step function with checkpoint/restart + straggler tracking."""

    def __init__(self, ckpt: Checkpointer, save_every: int = 50,
                 max_restarts: int = 3,
                 straggler: StragglerPolicy | None = None):
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.straggler = straggler or StragglerPolicy()
        self.report = SupervisorReport()
        self._durations: list[float] = []

    def run(self, *, state: Any, step_fn: Callable[[Any, int], Any],
            n_steps: int, restore_fn: Callable[[int | None], Any],
            save_aux_fn: Callable[[Any], dict] | None = None,
            start_step: int = 0) -> Any:
        """state: opaque training state (params, opt, data).
        step_fn(state, step) -> state.  restore_fn(step|None) -> (state,
        step) rebuilt from the latest checkpoint."""
        step = start_step
        while step < n_steps:
            try:
                t0 = time.monotonic()
                state = step_fn(state, step)
                dt = time.monotonic() - t0
                self._watch(dt, step, state, save_aux_fn)
                step += 1
                self.report.steps_run += 1
                if step % self.save_every == 0:
                    self.ckpt.save_async(
                        step, state,
                        aux=(save_aux_fn(state) if save_aux_fn else {}))
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                self.report.failures.append(
                    {"step": step, "error": repr(e), "time": time.time()})
                if self.report.restarts >= self.max_restarts:
                    raise
                self.report.restarts += 1
                self.ckpt.wait()
                state, step = restore_fn(None)
        self.ckpt.wait()
        return state

    def _watch(self, dt: float, step: int, state, save_aux_fn):
        self._durations.append(dt)
        if len(self._durations) > self.straggler.window:
            self._durations.pop(0)
        if len(self._durations) >= 8:
            med = median(self._durations)
            if dt > self.straggler.factor * med:
                self.report.stragglers.append(
                    {"step": step, "duration": dt, "median": med})
                if self.straggler.checkpoint_on_straggler:
                    self.ckpt.save_async(step, state, aux={})


def elastic_restore(ckpt: Checkpointer, like_tree, mesh, spec_tree,
                    rules=None, shapes=None, step: int | None = None):
    """Restore a checkpoint onto the CURRENT mesh (possibly a different
    device count than at save time)."""
    from . import sharding as sh
    shardings = sh.tree_shardings(mesh, spec_tree, rules, shapes=shapes)
    return ckpt.restore(like_tree, step=step, shardings=shardings)
