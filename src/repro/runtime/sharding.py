"""Logical-axis sharding rules (MaxText-style).

Model code annotates activations/params with *logical* axis names; a rule
table maps them to physical mesh axes.  Swapping the rule table is how the
perf loop changes sharding without touching model code (EXPERIMENTS.md
§Perf).  Outside a `use_mesh(...)` context every annotation is a no-op, so
the same model code runs in single-device tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None=replicated)
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),        # DP over pod+data
    "seq": None,                     # optionally "model" for SP (rule swap)
    "d_model": None,
    "heads": "model",                # TP: attention heads
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",                  # TP: MLP hidden
    "vocab": "model",                # TP: embedding/logits
    "experts": "model",              # EP: MoE experts
    "expert_ffn": None,
    "ssm_heads": "model",            # TP: Mamba inner heads
    "ssm_state": None,
    "kv_lora": None,
    "layers": None,                  # scan axis; "pod" under pipeline rules
    "groups": ("pod", "data"),       # MoE dispatch groups follow batch
    "conv": None,
    "frames": None,
    "kv_seq": None,              # KV-cache storage seq dim (decode/prefill
                                 # rules map it to "model": split-KV)
    "filter_bits": "model",      # big filter tables (words/table arrays):
                                 # FilterBank placement shards them over TP
}

# sequence-parallel rule swap: shard long sequences over the model axis
# (decode-time KV caches, norms).  Used by serve paths + perf iterations.
SP_RULES = dict(DEFAULT_RULES, seq="model", heads=None, kv_heads=None)

# decode rules: flash-decoding-style split-KV.  The KV cache's seq dim is
# sharded over `model` (GQA kv_heads < mesh width can't shard; a 32k cache
# can).  Weight shardings unchanged; the q-len-1 activations' "seq" axis
# degrades to replicated via divisibility.  Attention contractions over
# the sharded S produce partial sums + a tiny all-reduce — the GSPMD
# equivalent of split-KV decoding.
DECODE_RULES = dict(DEFAULT_RULES, kv_seq="model")

# prefill under memory pressure: cache stored seq-sharded, activations not
PREFILL_SPLITKV_RULES = dict(DEFAULT_RULES, kv_seq="model")

_state = threading.local()


def _ctx():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, Any] | None = None):
    """Activate mesh + logical rules for model annotations."""
    entry = (mesh, dict(DEFAULT_RULES if rules is None else rules))
    _ctx().append(entry)
    try:
        with mesh:
            yield mesh
    finally:
        _ctx().pop()


def active() -> tuple[Mesh, dict] | None:
    stack = _ctx()
    return stack[-1] if stack else None


def logical_to_spec(axes: Iterable[str | None],
                    rules: dict[str, Any]) -> P:
    """Map logical axis names to a PartitionSpec, dropping duplicate mesh
    axes (a mesh axis may appear only once in a spec)."""
    used: set[str] = set()
    parts = []
    for ax in axes:
        rule = rules.get(ax) if ax is not None else None
        if rule is None:
            parts.append(None)
            continue
        rs = (rule,) if isinstance(rule, str) else tuple(rule)
        keep = tuple(r for r in rs if r not in used)
        used.update(keep)
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(keep)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard(x, *axes: str | None):
    """Annotate an intermediate with logical axes (no-op without a mesh).
    Divisibility-aware: non-dividing mesh axes degrade to replicated."""
    ctx = active()
    if ctx is None:
        return x
    mesh, rules = ctx
    ns = spec_for(mesh, rules, axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, ns)


def heads_divisible(logical: str, n_heads: int) -> bool:
    """True iff the mesh extent mapped to `logical` divides n_heads —
    guards flat (B,T,H*Dh) head annotations: if whole heads don't divide,
    GSPMD would shard head_dim (a contraction dim) and all-reduce the
    attention scores (the llama4 40-heads-on-16 pathology, §Perf B1)."""
    ctx = active()
    if ctx is None:
        return True
    mesh, rules = ctx
    rule = rules.get(logical)
    if rule is None:
        return True
    rs = (rule,) if isinstance(rule, str) else tuple(rule)
    extent = 1
    for r in rs:
        if r in mesh.axis_names:
            extent *= mesh.shape[r]
    return n_heads % extent == 0


def spec_for(mesh: Mesh, rules: dict[str, Any], axes,
             shape=None) -> NamedSharding:
    """Resolve logical axes to a NamedSharding.  When `shape` is given the
    spec degrades gracefully: mesh axes whose extent does not divide the
    dim are dropped (explicit in_shardings require exact divisibility —
    e.g. 8 KV heads on a 16-wide model axis, vocab 51865, batch 1)."""
    spec = logical_to_spec(axes, rules)

    def keep(part, dim=None):
        if part is None:
            return None
        parts = part if isinstance(part, tuple) else (part,)
        kept = []
        extent = 1
        for p in parts:
            if p not in mesh.axis_names:
                continue
            n = mesh.shape[p]
            if dim is not None and dim % (extent * n) != 0:
                continue
            kept.append(p)
            extent *= n
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else tuple(kept)

    parts = list(spec)
    if shape is not None:
        parts = parts + [None] * (len(shape) - len(parts))
        parts = [keep(p, shape[i]) for i, p in enumerate(parts)]
    else:
        parts = [keep(p) for p in parts]
    while parts and parts[-1] is None:
        parts.pop()
    return NamedSharding(mesh, P(*parts))


def tree_shardings(mesh: Mesh, spec_tree, rules: dict[str, Any] | None = None,
                   shapes=None):
    """Map a pytree of logical-axis tuples to NamedShardings.  `shapes`
    (optional, same structure with .shape leaves) enables divisibility-
    aware degradation."""
    rules = dict(DEFAULT_RULES if rules is None else rules)
    if shapes is None:
        return jax.tree.map(
            lambda axes: spec_for(mesh, rules, axes),
            spec_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda axes, shp: spec_for(mesh, rules, axes, shape=shp.shape),
        spec_tree, shapes, is_leaf=lambda x: isinstance(x, tuple))
