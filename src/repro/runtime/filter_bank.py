"""`repro.runtime.filter_bank` — the multi-filter serving dispatcher.

Deployments run several heterogeneous filters side by side (ROADMAP
"multi-filter serving": an HABF admission gate, an n-gram blocklist, a
dedup Bloom, a fingerprint Xor cache index, ...) with very different
memory/accuracy profiles.  A `FilterBank` owns all of them for one pod:

  * `register(name, filter_or_artifact)` — any of the 7 typed pytree
    artifact kinds (or a live `Filter`, exported via `to_artifact()`).
  * mesh-aware placement — `place(artifact, mesh, policy)` replicates
    small tables (VMEM residency) and `jax.device_put`s the large
    `words`/`table` arrays sharded over the `model` axis above a byte
    threshold, reusing `runtime.sharding.spec_for` so non-dividing table
    lengths degrade to replicated instead of erroring.
  * one entrypoint — `bank.query(name, keys, ...)` / `bank.query_batch`
    dispatch through `repro.kernels.query`, and `bank.artifact(name)`
    hands the placed pytree to jitted serving steps (the fused gates in
    `runtime.serve_loop`), whose outcomes flow back via `bank.observe`.
  * per-filter telemetry — probe count, hit rate, estimated FP cost
    (cost-weighted hit mass, the weighted-FPR numerator of `core.costs` /
    paper §V-F), bytes resident, and kernel-vs-ref path counts fed by
    `kernels.dispatch.add_query_hook` (so even direct `query_keys` calls
    against a registered artifact are attributed).
  * `swap(name, artifact)` — the double-buffered publish point for the
    async-rebuild roadmap item: the new artifact is fully placed before
    the name flips to it, and the old one is returned still-valid for
    any in-flight jitted closures.
"""
from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import dispatch as _dispatch
from ..kernels.artifacts import NgramArtifact, _ArtifactBase
from ..kernels.dispatch import QueryEvent, query as _query, query_keys
from . import sharding as sh


@dataclass(frozen=True)
class PlacementPolicy:
    """Where each artifact leaf lives on the mesh.

    Leaves named in ``table_fields`` (the word-packed bit tables / Xor
    fingerprint slots — the only arrays that grow with the key set) are
    sharded over ``axis`` once they reach ``shard_bytes``; everything
    else (hash constants, HashExpressor cells, k-caches, classifier
    params) is small and replicated for VMEM residency."""
    shard_bytes: int = 1 << 20          # 1 MiB: below this, replicate
    axis: str = "model"
    table_fields: tuple = ("words", "table")


def _leaf_name(path) -> str:
    """Last attribute/dict key on a pytree path ('words', 'table', ...)."""
    for entry in reversed(path):
        name = getattr(entry, "name", getattr(entry, "key", None))
        if name is not None:
            return str(name)
    return ""


def _leaf_bytes(leaf) -> int:
    return int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize


def place(artifact, mesh: Mesh | None,
          policy: PlacementPolicy | None = None):
    """Place an artifact pytree on ``mesh`` -> (placed, report).

    report = {"sharded": [leaf names], "replicated": [...], "axis": ...,
    "bytes": total}.  With ``mesh=None`` the artifact is returned as-is
    (single-process default placement)."""
    policy = policy or PlacementPolicy()
    leaves = jax.tree_util.tree_flatten_with_path(artifact)[0]
    report = {"sharded": [], "replicated": [], "axis": policy.axis,
              "bytes": sum(_leaf_bytes(l) for _, l in leaves)}
    if mesh is None:
        return artifact, report
    rules = dict(sh.DEFAULT_RULES, filter_bits=policy.axis)
    shardings = {}
    for path, leaf in leaves:
        name = _leaf_name(path)
        if (name in policy.table_fields and leaf.ndim == 1
                and _leaf_bytes(leaf) >= policy.shard_bytes):
            ns = sh.spec_for(mesh, rules, ("filter_bits",), shape=leaf.shape)
        else:
            ns = NamedSharding(mesh, P())
        shardings[path] = ns
        (report["sharded"] if ns.spec else report["replicated"]).append(name)
    placed = jax.device_put(
        artifact, jax.tree_util.tree_map_with_path(
            lambda p, _: shardings[p], artifact))
    return placed, report


def _weak_hook(bank_ref):
    """Dispatch hook holding only a weakref to the bank, so an unclosed
    bank is still collectable; the hook unregisters itself once dead."""
    def hook(ev):
        bank = bank_ref()
        if bank is None:
            _dispatch.remove_query_hook(hook)
            return
        bank._on_query(ev)
    return hook


@dataclass
class _Entry:
    name: str
    artifact: object
    placement: dict
    policy: PlacementPolicy | None = None   # per-entry override, kept by swap
    version: int = 1
    queries: int = 0            # bank.query / observe calls
    keys: int = 0               # total elements probed
    hits: int = 0
    est_fp_cost: float = 0.0    # cost-weighted hit mass (§V-F numerator)
    kernel_queries: int = 0     # dispatch path attribution (query hook)
    ref_queries: int = 0
    fused_queries: int = 0      # probes fused into jitted serving steps

    def telemetry(self) -> dict:
        return {
            "kind": type(self.artifact).__name__,
            "version": self.version,
            "bytes": self.placement["bytes"],
            "placement": {k: self.placement[k]
                          for k in ("sharded", "replicated", "axis")},
            "queries": self.queries, "keys": self.keys, "hits": self.hits,
            "hit_rate": self.hits / self.keys if self.keys else 0.0,
            "est_fp_cost": self.est_fp_cost,
            "kernel_queries": self.kernel_queries,
            "ref_queries": self.ref_queries,
            "fused_queries": self.fused_queries,
        }


class FilterBank:
    """Registry + dispatcher + telemetry for every filter one pod serves."""

    def __init__(self, mesh: Mesh | None = None,
                 policy: PlacementPolicy | None = None, *,
                 use_kernel: bool = True, interpret: bool | None = None):
        self.mesh = mesh
        self.policy = policy or PlacementPolicy()
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._entries: dict[str, _Entry] = {}
        self._by_artifact: dict[int, _Entry] = {}
        self._lock = threading.Lock()
        self._pending: list = []   # (entry, device hits, costs) not yet
                                   # accounted — drained at telemetry time
        self._hook = _weak_hook(weakref.ref(self))
        _dispatch.add_query_hook(self._hook)

    # -- registry ------------------------------------------------------------
    def register(self, name: str, filt, *, policy=None):
        """Place and register an artifact (or a live `Filter`, exported
        first).  Returns the placed artifact.  A per-entry ``policy``
        override sticks to the entry and is reused by `swap`."""
        art = filt if isinstance(filt, _ArtifactBase) else filt.to_artifact()
        placed, rep = place(art, self.mesh, policy or self.policy)
        with self._lock:
            if name in self._entries:
                raise ValueError(f"filter {name!r} already registered; "
                                 "use swap() to publish a new version")
            e = _Entry(name, placed, rep, policy=policy)
            self._entries[name] = e
            self._by_artifact[id(placed)] = e
        return placed

    def swap(self, name: str, filt):
        """Double-buffered publish: fully place the new artifact (under
        the entry's registration-time policy), then atomically point
        ``name`` at it.  Returns the *old* artifact, which stays valid
        for in-flight jitted closures (the async rebuild's hot-swap
        point)."""
        art = filt if isinstance(filt, _ArtifactBase) else filt.to_artifact()
        pol = self._entries[name].policy or self.policy
        placed, rep = place(art, self.mesh, pol)           # buffer B built
        with self._lock:
            e = self._entries[name]                        # then flip
            old = e.artifact
            self._by_artifact.pop(id(old), None)
            e.artifact, e.placement = placed, rep
            e.version += 1
            self._by_artifact[id(placed)] = e
        return old

    def artifact(self, name: str):
        """The placed artifact — close it over into jitted serving steps."""
        return self._entries[name].artifact

    def names(self) -> list[str]:
        return list(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    # -- serving entrypoints -------------------------------------------------
    def query(self, name: str, keys, *, costs=None, use_kernel=None,
              interpret=None, **kw):
        """Serve one membership query batch against filter ``name``.

        ``keys``: uint64 fingerprints or strings (learned filters) — or a
        (B, T) int32 token batch for an `NgramArtifact`.  ``costs``
        optionally weights the telemetry FP-cost estimate (and the WBF
        query-side k recovery, as in `query_keys`)."""
        e = self._entries[name]
        uk = self.use_kernel if use_kernel is None else use_kernel
        ip = self.interpret if interpret is None else interpret
        if isinstance(e.artifact, NgramArtifact):
            out = _query(e.artifact, jnp.asarray(keys, jnp.int32),
                         use_kernel=uk, interpret=ip, **kw)
        else:
            out = query_keys(e.artifact, keys, use_kernel=uk, interpret=ip,
                             costs=costs, **kw)
        # hit/cost accounting is deferred to telemetry time: forcing the
        # device result to host here would put a sync point on the
        # serving hot path
        with self._lock:
            self._pending.append((e, out, costs))
        return out

    def query_batch(self, requests: dict, **kw) -> dict:
        """Serve several filters in one call: {name: keys} -> {name: hits}."""
        return {name: self.query(name, keys, **kw)
                for name, keys in requests.items()}

    def observe(self, name: str, hits, costs=None) -> None:
        """Account a probe outcome that happened *inside* a jitted serving
        step (the fused admission/blocklist gates of `serve_loop`), where
        the bank never saw the dispatch."""
        self._account(self._entries[name], np.asarray(hits), costs,
                      fused=True, count_query=True)

    def _account(self, e: _Entry, hits: np.ndarray, costs, *, fused: bool,
                 count_query: bool) -> None:
        """keys/hits/est_fp_cost move together so hit_rate stays a true
        ratio over the probes the bank accounted (bank.query + observe);
        direct dispatches show up in queries/path counters only."""
        hits = hits.astype(bool)
        n_hits = int(hits.sum())
        cost = (float((np.asarray(costs, np.float64) * hits.ravel()).sum())
                if costs is not None else float(n_hits))
        with self._lock:
            if count_query:
                e.queries += 1
            if fused:
                e.fused_queries += 1
            e.keys += int(hits.size)
            e.hits += n_hits
            e.est_fp_cost += cost

    def _on_query(self, ev: QueryEvent) -> None:
        """`kernels.dispatch` hook: attribute kernel-vs-ref path for any
        top-level query against a registered artifact.  Keys/hits are NOT
        counted here — the hook never sees the query outcome, and adding
        keys without hits would dilute hit_rate."""
        e = self._by_artifact.get(id(ev.artifact))
        if e is None:
            return
        with self._lock:
            e.queries += 1
            if ev.path == "kernel":
                e.kernel_queries += 1
            else:
                e.ref_queries += 1

    def _drain(self) -> None:
        """Realize deferred bank.query outcomes (one host transfer each,
        off the serving hot path)."""
        with self._lock:
            pending, self._pending = self._pending, []
        for e, out, costs in pending:
            self._account(e, np.asarray(out), costs, fused=False,
                          count_query=False)

    # -- telemetry -----------------------------------------------------------
    def telemetry(self, name: str | None = None) -> dict:
        self._drain()
        if name is not None:
            return self._entries[name].telemetry()
        return {n: e.telemetry() for n, e in self._entries.items()}

    def summary(self) -> str:
        """Human-readable per-filter serving table."""
        self._drain()
        hdr = (f"{'name':<12} {'kind':<16} {'ver':>3} {'bytes':>10} "
               f"{'queries':>8} {'keys':>10} {'hit_rate':>8} "
               f"{'fp_cost':>10} {'krnl/ref/fused':>14}  placement")
        lines = [hdr]
        for n, e in self._entries.items():
            t = e.telemetry()
            pl = (f"shard[{','.join(t['placement']['sharded'])}]"
                  f"@{t['placement']['axis']}"
                  if t["placement"]["sharded"] else "replicated")
            lines.append(
                f"{n:<12} {t['kind']:<16} {t['version']:>3} "
                f"{t['bytes']:>10} {t['queries']:>8} {t['keys']:>10} "
                f"{t['hit_rate']:>8.4f} {t['est_fp_cost']:>10.3g} "
                f"{t['kernel_queries']:>4}/{t['ref_queries']}/"
                f"{t['fused_queries']:<5}  {pl}")
        return "\n".join(lines)

    def close(self) -> None:
        _dispatch.remove_query_hook(self._hook)

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
