"""Checkpointing: atomic, async-capable, elastic-restore.

Layout: <dir>/step_<N>/ containing one .npy per pytree leaf (path-mangled)
plus manifest.json (tree structure, shapes, dtypes, step, config hash,
data-pipeline state).  Writes go to a tmp dir + os.replace rename so a
crash mid-save never corrupts the latest checkpoint (fault-tolerance
contract used by runtime/fault_tolerance.py).

Elastic restore: leaves are loaded on host then device_put against the
*current* mesh's NamedShardings — a checkpoint written on a 512-chip mesh
restores onto 256 (or 8) chips as long as the logical rules resolve
(tested in tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_paths(tree):
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append("__".join(_key_str(k) for k in kp))
    return paths


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"i{k.idx}"
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, aux: dict | None = None) -> Path:
        """Synchronous atomic save.  `tree` leaves are device or host arrays."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host, aux or {})

    def save_async(self, step: int, tree, aux: dict | None = None):
        """Snapshot to host now, write in a background thread (training
        continues).  Joins any previous in-flight save first."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, aux or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, aux: dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = _flatten(host_tree)
        paths = _leaf_paths(host_tree)
        manifest = {"step": step, "aux": aux, "time": time.time(),
                    "leaves": []}
        for i, (leaf, p) in enumerate(zip(leaves, paths)):
            fname = f"{i:05d}.npy"
            # ml_dtypes (bfloat16/float8) don't round-trip through np.save:
            # store a byte view + the logical dtype in the manifest.
            np.save(tmp / fname, np.ascontiguousarray(leaf).view(np.uint8))
            manifest["leaves"].append(
                {"file": fname, "path": p, "shape": list(leaf.shape),
                 "dtype": str(leaf.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: int | None = None,
                shardings=None) -> tuple:
        """Returns (tree, manifest).  `like_tree` provides the structure;
        `shardings` (same structure or None) re-places leaves onto the
        current mesh — the elastic-restore path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        leaves, treedef = _flatten(like_tree)
        assert len(leaves) == len(manifest["leaves"]), \
            f"leaf count mismatch: {len(leaves)} vs {len(manifest['leaves'])}"
        import ml_dtypes  # noqa: F401 — registers bfloat16/float8 dtypes
        loaded = []
        for e in manifest["leaves"]:
            raw = np.load(d / e["file"])
            loaded.append(raw.view(np.dtype(e["dtype"])).reshape(e["shape"]))
        tree = jax.tree.unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree,
                jax.tree.map(lambda s: s, shardings))
        return tree, manifest
