"""Typed device artifacts for every filter in the registry.

Each artifact is a frozen dataclass registered as a JAX pytree: array
tables are leaves, shape/meta (m, k, double_hash, ...) is static aux_data.
That means an artifact jits, vmaps, `jax.device_put`s with a sharding, and
closes over into serving steps cleanly — replacing the stringly table
dicts and 10+-positional-argument wrappers the seed code used.

Artifacts are produced by ``Filter.to_artifact()`` and consumed by the
single dispatching entrypoint ``repro.kernels.query``.  ``save``/
``load_artifact`` round-trip any artifact (including nested ones — a
learned filter holds its backup/pre Bloom artifacts and the classifier
params) through a single ``.npz`` file for serving hot-swap.
"""
from __future__ import annotations

import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

_ARTIFACT_KINDS: dict[str, type] = {}


def _register(cls, data_fields, meta_fields):
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=list(meta_fields))
    cls._data_fields = tuple(data_fields)
    cls._meta_fields = tuple(meta_fields)
    _ARTIFACT_KINDS[cls.__name__] = cls
    return cls


def _dev(x):
    """Leaf conversion: numpy/jnp array -> jnp; dicts, nested artifacts and
    None pass through."""
    if x is None or isinstance(x, (dict, _ArtifactBase)):
        return x
    return jnp.asarray(x)


class _ArtifactBase:
    """Shared construction + npz persistence for all artifact kinds."""

    @classmethod
    def from_arrays(cls, **kw):
        for f in cls._data_fields:
            v = kw[f]
            kw[f] = ({k: jnp.asarray(a) for k, a in v.items()}
                     if isinstance(v, dict) else _dev(v))
        return cls(**kw)

    def meta(self) -> dict:
        return {f: getattr(self, f) for f in self._meta_fields}

    # -- persistence ---------------------------------------------------------
    def save(self, path) -> None:
        arrays: dict[str, np.ndarray] = {}
        spec = _pack(self, "", arrays)
        np.savez(path, __spec__=np.frombuffer(
            json.dumps(spec).encode(), np.uint8), **arrays)

    def __eq__(self, other):
        if type(self) is not type(other):
            return NotImplemented
        if self.meta() != other.meta():
            return False
        sl = jax.tree_util.tree_leaves(self)
        ol = jax.tree_util.tree_leaves(other)
        return (len(sl) == len(ol)
                and all(a.shape == b.shape and a.dtype == b.dtype
                        and bool(jnp.array_equal(a, b))
                        for a, b in zip(sl, ol)))


def _pack(obj, prefix: str, arrays: dict) -> dict:
    if obj is None:
        return {"type": "none"}
    if isinstance(obj, _ArtifactBase):
        fields = {}
        for f in obj._data_fields:
            fields[f] = _pack(getattr(obj, f), f"{prefix}{f}.", arrays)
        return {"type": "artifact", "kind": type(obj).__name__,
                "meta": obj.meta(), "fields": fields}
    if isinstance(obj, dict):
        for k, v in obj.items():
            arrays[f"{prefix}{k}"] = np.asarray(v)
        return {"type": "dict", "keys": sorted(obj)}
    arrays[prefix.rstrip(".")] = np.asarray(obj)
    return {"type": "array"}


def _unpack(spec: dict, prefix: str, arrays) -> object:
    t = spec["type"]
    if t == "none":
        return None
    if t == "array":
        return jnp.asarray(arrays[prefix.rstrip(".")])
    if t == "dict":
        return {k: jnp.asarray(arrays[f"{prefix}{k}"]) for k in spec["keys"]}
    cls = _ARTIFACT_KINDS[spec["kind"]]
    kw = dict(spec["meta"])
    for f, sub in spec["fields"].items():
        kw[f] = _unpack(sub, f"{prefix}{f}.", arrays)
    return cls(**kw)


def load_artifact(path):
    """Load any artifact previously written by ``Artifact.save``."""
    with np.load(path) as z:
        spec = json.loads(bytes(z["__spec__"]).decode())
        return _unpack(spec, "", z)


# ---------------------------------------------------------------------------
# artifact kinds
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class BloomArtifact(_ArtifactBase):
    """k-probe Bloom table.  For ``double_hash`` only the two base mixers
    are carried (rows 0/1); otherwise constants are pre-gathered per H0
    index so the kernel never indexes the global family."""
    words: jnp.ndarray          # (W,) uint32 word-packed bits
    c1: jnp.ndarray             # (k,) uint32  ((2,) when double_hash)
    c2: jnp.ndarray
    mul: jnp.ndarray
    m: int                      # static: number of bits
    k: int                      # static: probes per key
    double_hash: bool = False   # static: Kirsch–Mitzenmacher g_i = h_a+i*h_b


_register(BloomArtifact, ["words", "c1", "c2", "mul"],
          ["m", "k", "double_hash"])


@dataclass(frozen=True, eq=False)
class HABFArtifact(_ArtifactBase):
    """Fused two-round HABF query state: Bloom words + HashExpressor cell
    arrays + the full hash family (the walk gathers by stored index)."""
    words: jnp.ndarray          # (W,) uint32
    hx_hashidx: jnp.ndarray     # (omega,) int32, 0 = empty cell
    hx_endbit: jnp.ndarray      # (omega,) int32
    c1: jnp.ndarray             # (n_hash,) uint32 global family
    c2: jnp.ndarray
    mul: jnp.ndarray
    f_consts: jnp.ndarray       # (3, 1) uint32 — unified hash f of the walk
    h0_idx: jnp.ndarray         # (k,) int32 round-1 hash indices
    m: int                      # static
    omega: int                  # static
    k: int                      # static
    double_hash: bool = False   # static (f-HABF)

    @classmethod
    def from_filter(cls, habf) -> "HABFArtifact":
        from ..core.hash_expressor import F_FAMILY
        bf, hx = habf.bf, habf.hx
        fam = bf.family
        f_consts = np.stack([F_FAMILY["c1"], F_FAMILY["c2"], F_FAMILY["mul"]])
        return cls.from_arrays(
            words=bf.bits.words, hx_hashidx=hx.hashidx.astype(np.int32),
            hx_endbit=hx.endbit.astype(np.int32), c1=fam["c1"], c2=fam["c2"],
            mul=fam["mul"], f_consts=f_consts,
            h0_idx=bf.hash_idx.astype(np.int32), m=bf.bits.m, omega=hx.omega,
            k=hx.k, double_hash=hx.double_hash)


_register(HABFArtifact,
          ["words", "hx_hashidx", "hx_endbit", "c1", "c2", "mul",
           "f_consts", "h0_idx"],
          ["m", "omega", "k", "double_hash"])


@dataclass(frozen=True, eq=False)
class XorArtifact(_ArtifactBase):
    """Xor filter table + the 4-function fingerprint family (3 slot
    hashes + 1 fingerprint hash); the per-round key salt is derived from
    the static ``seed_round``."""
    table: jnp.ndarray          # (3 * seg_len,) uint32 fingerprints
    c1: jnp.ndarray             # (4,) uint32
    c2: jnp.ndarray
    mul: jnp.ndarray
    seg_len: int                # static
    fp_bits: int                # static
    seed_round: int             # static


_register(XorArtifact, ["table", "c1", "c2", "mul"],
          ["seg_len", "fp_bits", "seed_round"])


@dataclass(frozen=True, eq=False)
class WBFArtifact(_ArtifactBase):
    """Weighted-Bloom table (k_max probe constants) + the top-cost k-cache
    as sorted leaf arrays so query wrappers can reproduce the host's
    cached-k lookup without the host dict."""
    words: jnp.ndarray          # (W,) uint32
    c1: jnp.ndarray             # (k_max,) uint32
    c2: jnp.ndarray
    mul: jnp.ndarray
    cache_lo: jnp.ndarray       # (n_cache,) uint32, sorted by full u64 key
    cache_hi: jnp.ndarray
    cache_k: jnp.ndarray        # (n_cache,) int32
    m: int                      # static
    k_bar: int                  # static: nominal probe count
    k_max: int                  # static
    k_fallback: int             # static: uncached-key probes (zero-FNR floor)


_register(WBFArtifact,
          ["words", "c1", "c2", "mul", "cache_lo", "cache_hi", "cache_k"],
          ["m", "k_bar", "k_max", "k_fallback"])


@dataclass(frozen=True, eq=False)
class LearnedArtifact(_ArtifactBase):
    """LBF / SLBF: classifier params + threshold + backup (and optional
    pre) Bloom artifacts.  Queries additionally need the byte-encoded key
    strings (``bytes_mat``) to featurize."""
    params: dict                # classifier weights (dict of arrays)
    backup: BloomArtifact
    pre: BloomArtifact | None   # SLBF initial filter
    model_kind: str             # static: "mlp" | "gru"
    tau: float                  # static decision threshold


_register(LearnedArtifact, ["params", "backup", "pre"],
          ["model_kind", "tau"])


@dataclass(frozen=True, eq=False)
class AdaBFArtifact(_ArtifactBase):
    """Ada-BF: classifier params + score-bucket edges/hash counts over a
    single Bloom table."""
    params: dict
    bf: BloomArtifact
    taus: jnp.ndarray           # (g-1,) float32 bucket edges
    ks: jnp.ndarray             # (g,) int32 hashes per bucket
    model_kind: str             # static


_register(AdaBFArtifact, ["params", "bf", "taus", "ks"], ["model_kind"])


@dataclass(frozen=True, eq=False)
class NgramArtifact(_ArtifactBase):
    """Token n-gram blocklist: Bloom table + pre-gathered probe constants
    + the static n-gram order.  Queried with a (B, T) token batch."""
    words: jnp.ndarray          # (W,) uint32
    c1: jnp.ndarray             # (k,) uint32
    c2: jnp.ndarray
    mul: jnp.ndarray
    m: int                      # static
    k: int                      # static
    n: int                      # static n-gram length

    @classmethod
    def from_filter(cls, bf, n: int) -> "NgramArtifact":
        fam, idx = bf.family, bf.hash_idx
        return cls.from_arrays(words=bf.bits.words, c1=fam["c1"][idx],
                               c2=fam["c2"][idx], mul=fam["mul"][idx],
                               m=bf.bits.m, k=bf.k, n=n)


_register(NgramArtifact, ["words", "c1", "c2", "mul"], ["m", "k", "n"])
