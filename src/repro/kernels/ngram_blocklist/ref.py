"""Pure-jnp oracle: rolling n-gram fingerprints of a token batch, probed
against a word-packed Bloom blocklist (decode-path integration of HABF's
filters; DESIGN.md §2)."""
from __future__ import annotations

import jax.numpy as jnp

from .. import common

# positional salts for n-gram combination (distinct odd constants; kept as
# Python ints so kernel bodies bake them in as scalars, not captured arrays)
_POS = (0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
        0x165667B1, 0x9E3779B9, 0x85EBCA6B, 0xC2B2AE35)


def ngram_fingerprints(tokens, n: int):
    """tokens (B, T) int32 -> (lo, hi) uint32 fingerprints of the trailing
    n-gram ending at each position; positions < n-1 fold in zero padding."""
    t = tokens.astype(jnp.uint32)
    lo = jnp.zeros(t.shape, jnp.uint32)
    hi = jnp.zeros(t.shape, jnp.uint32)
    for i in range(n):
        shifted = jnp.pad(t, ((0, 0), (i, 0)))[:, : t.shape[1]]
        e = common.mix32(shifted ^ jnp.uint32(_POS[i % len(_POS)]))
        lo = lo + e * jnp.uint32(2 * i + 1)
        hi = hi ^ common.mix32(e + jnp.uint32(i))
    return common.mix32(lo), common.mix32(hi ^ lo)


def ngram_blocklist_ref(tokens, words, c1, c2, mul, m: int, k: int, n: int):
    """Returns (B, T) bool — True where the trailing n-gram hits the list."""
    lo, hi = ngram_fingerprints(tokens, n)
    acc = jnp.ones(lo.shape, jnp.uint32)
    for j in range(k):
        hv = common.hash_value(lo, hi, c1[j], c2[j], mul[j])
        acc = acc & common.probe_bits(words, common.fastrange(hv, m))
    # positions without a complete n-gram never match
    pos = jnp.arange(tokens.shape[1])[None, :]
    return (acc & (pos >= n - 1)).astype(jnp.bool_)
