"""jit'd public wrapper for the fused n-gram blocklist scan."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import ngram_blocklist_pallas
from .ref import ngram_blocklist_ref, ngram_fingerprints


@partial(jax.jit, static_argnames=("m", "k", "n", "use_kernel", "interpret"))
def ngram_blocklist(tokens, words, c1, c2, mul, *, m: int, k: int, n: int,
                    use_kernel: bool = True, interpret: bool | None = None):
    if use_kernel:
        out = ngram_blocklist_pallas(tokens, words, c1, c2, mul, m, k, n,
                                     interpret=interpret)
        return out.astype(jnp.bool_)
    return ngram_blocklist_ref(tokens, words, c1, c2, mul, m, k, n)


def build_blocklist_bf(ngrams: np.ndarray, m_bits: int, k: int):
    """Host helper: build a Bloom blocklist over (n_entries, n) token
    n-grams using the *same* fingerprint scheme as the kernel, so device
    scans agree with host inserts."""
    from ...core.bloom import BloomFilter

    toks = jnp.asarray(ngrams, jnp.int32)
    lo, hi = ngram_fingerprints(toks, toks.shape[1])
    fp = (np.asarray(hi[:, -1], np.uint64) << np.uint64(32)) | \
        np.asarray(lo[:, -1], np.uint64)
    bf = BloomFilter(m_bits, k)
    bf.insert(fp)
    return bf


def build_blocklist(ngrams: np.ndarray, m_bits: int, k: int):
    """Build the typed device artifact for an n-gram blocklist; query it
    with `repro.kernels.query(artifact, tokens)`."""
    from ..artifacts import NgramArtifact

    ngrams = np.asarray(ngrams)
    bf = build_blocklist_bf(ngrams, m_bits, k)
    return NgramArtifact.from_filter(bf, n=int(ngrams.shape[1]))
