"""Pallas TPU kernel: fused rolling n-gram fingerprint + Bloom probe.

Fuses the serving hot path: instead of materializing (B, T) fingerprints
in HBM and launching a separate probe, each tile of tokens is hashed and
probed in-register.  Tiling: grid over batch rows; each step processes
(_BT, T) token rows — a 32k-token row is 128 KB, so a full row tile plus
the VMEM-resident blocklist fits comfortably (the n-gram window then
needs no halo exchange between tiles).  The n-token window is combined
with static shifts (jnp.pad + slice), so there is no data-dependent
control flow."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import common
from .ref import _POS

_BT = 8  # batch rows per grid step


def _kernel(tok_ref, words_ref, c1_ref, c2_ref, mul_ref, out_ref,
            *, m: int, k: int, n: int, t_total: int):
    tok = tok_ref[...].astype(jnp.uint32)          # (_BT, Tp)
    words = words_ref[...]
    lo = jnp.zeros_like(tok)
    hi = jnp.zeros_like(tok)
    for i in range(n):
        shifted = jnp.pad(tok, ((0, 0), (i, 0)))[:, : tok.shape[1]]
        e = common.mix32(shifted ^ jnp.uint32(_POS[i % len(_POS)]))
        lo = lo + e * jnp.uint32(2 * i + 1)
        hi = hi ^ common.mix32(e + jnp.uint32(i))
    lo, hi = common.mix32(lo), common.mix32(hi ^ lo)
    acc = jnp.ones_like(tok)
    for j in range(k):
        hv = common.hash_value(lo, hi, c1_ref[j], c2_ref[j], mul_ref[j])
        idx = common.fastrange(hv, m)
        word = jnp.take(words, (idx >> 5).astype(jnp.int32).reshape(-1),
                        axis=0, mode="clip").reshape(idx.shape)
        acc = acc & ((word >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1))
    pos = jnp.arange(tok.shape[1])[None, :]
    valid = (pos >= n - 1) & (pos < t_total)
    out_ref[...] = acc & valid.astype(jnp.uint32)


def ngram_blocklist_pallas(tokens, words, c1, c2, mul, m: int, k: int,
                           n: int, interpret: bool | None = None):
    """tokens (B, T) int32 -> (B, T) uint32 hit flags."""
    if interpret is None:
        interpret = common.TPU_INTERPRET
    B, T = tokens.shape
    tp, _ = common.pad_to(tokens, 128, axis=1)
    tp, _ = common.pad_to(tp, _BT, axis=0)
    Bp, Tp = tp.shape

    kern = partial(_kernel, m=m, k=k, n=n, t_total=T)
    out = pl.pallas_call(
        kern,
        grid=(Bp // _BT,),
        in_specs=[
            pl.BlockSpec((_BT, Tp), lambda i: (i, 0)),
            pl.BlockSpec(words.shape, lambda i: (0,)),
            pl.BlockSpec(c1.shape, lambda i: (0,)),
            pl.BlockSpec(c2.shape, lambda i: (0,)),
            pl.BlockSpec(mul.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BT, Tp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, Tp), jnp.uint32),
        interpret=interpret,
    )(tp, words, c1, c2, mul)
    return out[:B, :T]
