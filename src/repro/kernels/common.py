"""Shared device-side hashing helpers for the filter kernels.

All functions are jnp-only (traceable inside Pallas kernel bodies and in
the pure-jnp reference oracles).  They mirror `core.hashing`'s numpy
implementations bit-exactly — tested in tests/test_hashing.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import (_mix32_jnp, hash_value_jnp, umulhi32_jnp,
                            fastrange_jnp)

TPU_INTERPRET = jax.default_backend() != "tpu"  # interpret kernels off-TPU


def mix32(x):
    return _mix32_jnp(x)


def hash_value(key_lo, key_hi, c1, c2, mul):
    return hash_value_jnp(key_lo, key_hi, c1, c2, mul)


def double_hash_value(key_lo, key_hi, i, c1, c2, mul):
    """f-HABF double hashing: g_i = h_a + i * h_b (i may be a vector)."""
    ha = hash_value_jnp(key_lo, key_hi, c1[0], c2[0], mul[0])
    hb = hash_value_jnp(key_lo, key_hi, c1[1], c2[1], mul[1]) | jnp.uint32(1)
    return ha + jnp.asarray(i, jnp.uint32) * hb


def fastrange(h, m):
    return fastrange_jnp(h, m)


def probe_bits(words, idx):
    """Gather bit `idx` from a word-packed uint32 bit vector.

    TPU note: `jnp.take` over a VMEM-resident 1-D uint32 array lowers to a
    lane gather on current Mosaic; the whole filter (paper default 2 MB)
    is pinned in VMEM by the caller's BlockSpec, so probes never touch HBM.
    """
    word = jnp.take(words, (idx >> 5).astype(jnp.int32), axis=0,
                    mode="clip")
    return (word >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1)


def pad_to(x: jnp.ndarray, mult: int, axis: int = 0, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n
