"""Pallas TPU kernels for the HABF hot paths (validated in interpret mode
on CPU; see each kernel's ref.py for the pure-jnp oracle)."""
from .bloom_query.ops import bloom_query, bloom_query_u64
from .habf_query.ops import habf_query, habf_query_u64, device_tables
from .ngram_blocklist.ops import ngram_blocklist, build_blocklist_bf

__all__ = ["bloom_query", "bloom_query_u64", "habf_query", "habf_query_u64",
           "device_tables", "ngram_blocklist", "build_blocklist_bf"]
