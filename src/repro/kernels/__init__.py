"""Pallas TPU kernels for the HABF hot paths (validated in interpret mode
on CPU; see each kernel's ref.py for the pure-jnp oracle).

Public surface: typed pytree artifacts (`artifacts`) + the single
dispatching entrypoint `query` / host convenience `query_keys`.  Every
artifact type has a kernel path: Bloom/HABF/ngram/Xor/WBF run dedicated
Pallas kernels, Ada-BF rides the WBF kernel for its score-bucketed
variable-k probe, and learned (LBF/SLBF) artifacts route their backup/pre
Bloom probes through the Bloom kernel — `use_kernel` is honored
everywhere, never silently ignored.
"""
from .artifacts import (AdaBFArtifact, BloomArtifact, HABFArtifact,
                        LearnedArtifact, NgramArtifact, WBFArtifact,
                        XorArtifact, load_artifact)
from .dispatch import (add_query_hook, artifact_ref, query, query_keys,
                       remove_query_hook, QueryEvent)
from .bloom_query.ops import bloom_query
from .habf_query.ops import habf_query
from .ngram_blocklist.ops import (ngram_blocklist, build_blocklist,
                                  build_blocklist_bf)
from .wbf_query.ops import wbf_query
from .xor_query.ops import xor_query

__all__ = [
    "query", "query_keys", "load_artifact", "artifact_ref",
    "add_query_hook", "remove_query_hook", "QueryEvent",
    "BloomArtifact", "HABFArtifact", "XorArtifact", "WBFArtifact",
    "LearnedArtifact", "AdaBFArtifact", "NgramArtifact",
    "bloom_query", "habf_query", "xor_query", "wbf_query",
    "ngram_blocklist", "build_blocklist", "build_blocklist_bf",
]
