"""Pallas TPU kernels for the HABF hot paths (validated in interpret mode
on CPU; see each kernel's ref.py for the pure-jnp oracle).

Public surface: typed pytree artifacts (`artifacts`) + the single
dispatching entrypoint `query` / host convenience `query_keys`.  The old
`*_u64` helpers and `device_tables` remain as deprecation shims.
"""
from .artifacts import (AdaBFArtifact, BloomArtifact, HABFArtifact,
                        LearnedArtifact, NgramArtifact, WBFArtifact,
                        XorArtifact, load_artifact)
from .dispatch import query, query_keys
from .bloom_query.ops import bloom_query, bloom_query_u64
from .habf_query.ops import habf_query, habf_query_u64, device_tables
from .ngram_blocklist.ops import (ngram_blocklist, build_blocklist,
                                  build_blocklist_bf)

__all__ = [
    "query", "query_keys", "load_artifact",
    "BloomArtifact", "HABFArtifact", "XorArtifact", "WBFArtifact",
    "LearnedArtifact", "AdaBFArtifact", "NgramArtifact",
    "bloom_query", "bloom_query_u64", "habf_query", "habf_query_u64",
    "device_tables", "ngram_blocklist", "build_blocklist",
    "build_blocklist_bf",
]
