"""`repro.kernels.query` — the single device-query entrypoint.

Dispatches on the artifact's type (see artifacts.py) instead of threading
10+ positional arrays into per-kernel wrappers:

    art = filt.to_artifact()              # typed pytree
    hits = query(art, key_lo, key_hi)     # Pallas kernel or jnp ref

``query_keys(filter_or_artifact, keys)`` is the host-side convenience that
normalizes raw keys (uint64 fingerprints or strings) into the device
layout.

Kernel coverage: ``use_kernel`` is honored for *every* artifact type —
never accepted-and-ignored.  Bloom/HABF/ngram/Xor/WBF artifacts run their
dedicated Pallas kernels (interpret mode off-TPU); Ada-BF routes its
score-bucketed variable-k probe through the WBF kernel; learned (LBF/
SLBF) artifacts run the classifier via jitted apply and route their
backup/pre Bloom probes through the Bloom kernel.  ``use_kernel=False``
selects the pure-jnp reference path everywhere.
"""
from __future__ import annotations

import threading
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.hashing import as_str_keys, as_u64_keys, split_u64
from ..core.wbf import ks_for_costs
from .artifacts import (AdaBFArtifact, BloomArtifact, HABFArtifact,
                        LearnedArtifact, NgramArtifact, WBFArtifact,
                        XorArtifact, _ArtifactBase)
from .bloom_query.ops import bloom_query
from .bloom_query.ref import bloom_query_ref
from .habf_query.ops import habf_query
from .ngram_blocklist.ops import ngram_blocklist
from .wbf_query.ops import wbf_query
from .wbf_query.ref import wbf_query_ref
from .xor_query.ops import xor_query
from .xor_query.ref import xor_query_ref


# ---------------------------------------------------------------------------
# pure-jnp artifact queries (traceable; usable inside larger jitted steps)
# ---------------------------------------------------------------------------

def bloom_artifact_ref(art: BloomArtifact, key_lo, key_hi):
    """Traceable Bloom probe over an artifact -> bool (n,)."""
    return bloom_query_ref(key_lo, key_hi, art.words, art.c1, art.c2,
                           art.mul, art.m, art.k, double_hash=art.double_hash)


def habf_artifact_ref(art: HABFArtifact, key_lo, key_hi):
    """Traceable fused two-round HABF query over an artifact -> bool (n,)."""
    from .habf_query.ref import habf_query_ref
    return habf_query_ref(key_lo, key_hi, art.words, art.hx_hashidx,
                          art.hx_endbit, art.c1, art.c2, art.mul,
                          art.f_consts[0], art.f_consts[1], art.f_consts[2],
                          art.h0_idx, art.m, art.omega, art.k,
                          double_hash=art.double_hash)


def xor_artifact_ref(art: XorArtifact, key_lo, key_hi):
    """Traceable Xor-filter query (3 slot gathers + fingerprint compare)."""
    return xor_query_ref(key_lo, key_hi, art.table, art.c1, art.c2, art.mul,
                         art.seg_len, art.fp_bits, art.seed_round)


def wbf_artifact_ref(art: WBFArtifact, key_lo, key_hi, ks):
    """Traceable WBF query: probe all k_max bits, mask by per-key ks."""
    return wbf_query_ref(key_lo, key_hi, ks, art.words, art.c1, art.c2,
                         art.mul, art.m, art.k_max)


def _learned_decision(art: LearnedArtifact, scores, key_lo, key_hi, probe):
    """The one LBF/SLBF decision rule, shared by the reference and kernel
    paths so they cannot diverge.  ``probe(bloom_art) -> bool (n,)`` picks
    how the pre/backup Bloom tables are queried."""
    res = jnp.ones(key_lo.shape, jnp.bool_)
    if art.pre is not None:
        res = res & probe(art.pre)
    backup = probe(art.backup)
    return res & ((scores >= art.tau) | backup)


def learned_artifact_ref(art: LearnedArtifact, scores, key_lo, key_hi):
    """Traceable LBF/SLBF decision given classifier scores."""
    return _learned_decision(art, scores, key_lo, key_hi,
                             lambda bf: bloom_artifact_ref(bf, key_lo,
                                                           key_hi))


def adabf_ks(art: AdaBFArtifact, scores):
    """Per-key hash counts from classifier scores: score bucket -> k.
    Shared by the reference and kernel paths so they cannot diverge."""
    return jnp.take(art.ks, jnp.searchsorted(art.taus, scores),
                    mode="clip").astype(jnp.int32)


def adabf_artifact_ref(art: AdaBFArtifact, scores, key_lo, key_hi):
    """Traceable Ada-BF decision: score bucket -> hash count -> probes.
    The probe is exactly a WBF probe over the underlying Bloom table."""
    return wbf_query_ref(key_lo, key_hi, adabf_ks(art, scores),
                         art.bf.words, art.bf.c1, art.bf.c2, art.bf.mul,
                         art.bf.m, art.bf.k)


_learned_jit = jax.jit(learned_artifact_ref)
_adabf_ks_jit = jax.jit(adabf_ks)

_APPLY_JIT: dict[str, object] = {}


def classifier_scores(model_kind: str, params, bytes_mat):
    """Classifier scores for learned artifacts, chunked exactly like the
    host `score_fn` so host and device decisions agree bit-for-bit."""
    from ..core import learned
    if model_kind not in _APPLY_JIT:
        apply = learned.apply_mlp if model_kind == "mlp" else learned.apply_gru
        _APPLY_JIT[model_kind] = jax.jit(apply)
    apply_j = _APPLY_JIT[model_kind]
    out = []
    for i in range(0, len(bytes_mat), 65536):
        out.append(jax.nn.sigmoid(apply_j(params, bytes_mat[i:i + 65536])))
    return (jnp.concatenate(out) if out else jnp.zeros((0,), jnp.float32))


# ---------------------------------------------------------------------------
# query telemetry hooks
# ---------------------------------------------------------------------------

class QueryEvent(NamedTuple):
    """One top-level `query` dispatch, as seen by telemetry hooks.

    ``artifact`` is the object queried (identity-comparable — a FilterBank
    maps it back to an entry name), ``kind`` its type name, ``path`` which
    implementation served it ("kernel" | "ref"), and ``n`` the number of
    probed elements (keys, or window positions for n-gram batches).
    """
    artifact: object
    kind: str
    path: str
    n: int


_QUERY_HOOKS: list[Callable[[QueryEvent], None]] = []
_query_tls = threading.local()   # per-thread dispatch depth (serving threads)


def add_query_hook(fn: Callable[[QueryEvent], None]):
    """Register a telemetry hook fired once per *top-level* `query` call
    (nested dispatches — e.g. a learned artifact routing its backup Bloom
    probe back through `query` — are folded into the outer event)."""
    _QUERY_HOOKS.append(fn)
    return fn


def remove_query_hook(fn: Callable[[QueryEvent], None]) -> None:
    if fn in _QUERY_HOOKS:
        _QUERY_HOOKS.remove(fn)


# ---------------------------------------------------------------------------
# the entrypoint
# ---------------------------------------------------------------------------

def artifact_ref(art, key_lo, key_hi, ks=None):
    """Traceable membership probe over a table-backed artifact — the
    dispatcher analogue of `query(..., use_kernel=False)` that closes over
    into larger jitted steps (serving gates).  Learned/Ada-BF artifacts
    need host-side featurization and are rejected; route those through
    `query`/`query_keys` instead."""
    if isinstance(art, BloomArtifact):
        return bloom_artifact_ref(art, key_lo, key_hi)
    if isinstance(art, HABFArtifact):
        return habf_artifact_ref(art, key_lo, key_hi)
    if isinstance(art, XorArtifact):
        return xor_artifact_ref(art, key_lo, key_hi)
    if isinstance(art, WBFArtifact):
        if ks is None:
            ks = jnp.full(key_lo.shape, art.k_fallback, jnp.int32)
        return wbf_artifact_ref(art, key_lo, key_hi, ks)
    raise TypeError(f"{type(art).__name__} cannot close into a jitted gate "
                    "(needs host featurization); use query/query_keys")


def query(artifact, key_lo, key_hi=None, *, use_kernel: bool = True,
          interpret: bool | None = None, ks=None, bytes_mat=None):
    """Unified device membership query -> bool array.

    * Bloom/HABF/WBF/Xor/learned artifacts take ``key_lo``/``key_hi``
      (n,)-shaped uint32 key halves (see ``hashing.split_u64``).
    * ``NgramArtifact`` takes a (B, T) int32 token batch as the first
      array argument and flags the trailing n-gram at every position.
    * WBF takes optional per-key hash counts ``ks`` (defaults to the
      artifact's ``k_fallback`` zero-FNR floor).
    * Learned artifacts need ``bytes_mat`` (``learned.encode_keys`` of the
      raw strings) to featurize; use ``query_keys`` to get this for free.

    ``use_kernel`` selects the Pallas kernel path (interpret mode off-TPU)
    and is honored for every artifact type; ``use_kernel=False`` runs the
    pure-jnp reference.
    """
    depth = getattr(_query_tls, "depth", 0)
    _query_tls.depth = depth + 1
    try:
        out = _query_impl(artifact, key_lo, key_hi, use_kernel=use_kernel,
                          interpret=interpret, ks=ks, bytes_mat=bytes_mat)
    finally:
        _query_tls.depth = depth
    if depth == 0 and _QUERY_HOOKS:
        n = int(getattr(key_lo, "size", 0))
        # empty batches short-circuit to the jnp zeros path: no kernel ran
        path = "kernel" if use_kernel and n else "ref"
        ev = QueryEvent(artifact, type(artifact).__name__, path, n)
        for fn in list(_QUERY_HOOKS):
            fn(ev)
    return out


def _query_impl(artifact, key_lo, key_hi, *, use_kernel, interpret, ks,
                bytes_mat):
    if getattr(key_lo, "size", 1) == 0:
        # empty batch: nothing to probe (the Pallas grid can't be empty)
        return jnp.zeros(getattr(key_lo, "shape", (0,)), jnp.bool_)
    if isinstance(artifact, BloomArtifact):
        return bloom_query(key_lo, key_hi, artifact.words, artifact.c1,
                           artifact.c2, artifact.mul, m=artifact.m,
                           k=artifact.k, double_hash=artifact.double_hash,
                           use_kernel=use_kernel, interpret=interpret)
    if isinstance(artifact, HABFArtifact):
        return habf_query(key_lo, key_hi, artifact.words,
                          artifact.hx_hashidx, artifact.hx_endbit,
                          artifact.c1, artifact.c2, artifact.mul,
                          artifact.f_consts, artifact.h0_idx, m=artifact.m,
                          omega=artifact.omega, k=artifact.k,
                          double_hash=artifact.double_hash,
                          use_kernel=use_kernel, interpret=interpret)
    if isinstance(artifact, NgramArtifact):
        if key_hi is not None:
            raise TypeError("NgramArtifact queries take a (B, T) token "
                            "batch as the only array argument")
        return ngram_blocklist(key_lo, artifact.words, artifact.c1,
                               artifact.c2, artifact.mul, m=artifact.m,
                               k=artifact.k, n=artifact.n,
                               use_kernel=use_kernel, interpret=interpret)
    if isinstance(artifact, XorArtifact):
        return xor_query(key_lo, key_hi, artifact.table, artifact.c1,
                         artifact.c2, artifact.mul, seg_len=artifact.seg_len,
                         fp_bits=artifact.fp_bits,
                         seed_round=artifact.seed_round,
                         use_kernel=use_kernel, interpret=interpret)
    if isinstance(artifact, WBFArtifact):
        if ks is None:
            ks = jnp.full(key_lo.shape, artifact.k_fallback, jnp.int32)
        return wbf_query(key_lo, key_hi, jnp.asarray(ks), artifact.words,
                         artifact.c1, artifact.c2, artifact.mul,
                         m=artifact.m, k_max=artifact.k_max,
                         use_kernel=use_kernel, interpret=interpret)
    if isinstance(artifact, (LearnedArtifact, AdaBFArtifact)):
        if bytes_mat is None:
            raise ValueError("learned artifacts need bytes_mat= (the "
                             "byte-encoded key strings); see query_keys")
        scores = classifier_scores(artifact.model_kind, artifact.params,
                                   bytes_mat)
        if isinstance(artifact, AdaBFArtifact):
            # Ada-BF's score-bucketed probe IS a WBF probe over its table
            bf = artifact.bf
            return wbf_query(key_lo, key_hi, _adabf_ks_jit(artifact, scores),
                             bf.words, bf.c1, bf.c2, bf.mul, m=bf.m,
                             k_max=bf.k, use_kernel=use_kernel,
                             interpret=interpret)
        if not use_kernel:
            return _learned_jit(artifact, scores, key_lo, key_hi)
        # kernel path: classifier scoring stays a jitted apply (fusing it
        # into the probe kernel is a separate roadmap item); the backup /
        # pre Bloom probes run the bloom kernel
        return _learned_decision(
            artifact, scores, key_lo, key_hi,
            lambda bf: query(bf, key_lo, key_hi, use_kernel=True,
                             interpret=interpret))
    raise TypeError(f"not a filter artifact: {type(artifact).__name__}")


def _wbf_cached_ks(art: WBFArtifact, keys_u64: np.ndarray) -> np.ndarray:
    """Host-side reproduction of the WBF cached-k lookup from the
    artifact's sorted cache arrays."""
    cache = ((np.asarray(art.cache_hi, np.uint64) << np.uint64(32))
             | np.asarray(art.cache_lo, np.uint64))
    ck = np.asarray(art.cache_k, np.int64)
    if len(cache) == 0:
        return np.full(keys_u64.shape, art.k_fallback, np.int64)
    pos = np.minimum(np.searchsorted(cache, keys_u64), len(cache) - 1)
    found = cache[pos] == keys_u64
    return np.where(found, ck[pos], art.k_fallback)


def query_keys(obj, keys, *, use_kernel: bool = True,
               interpret: bool | None = None, costs=None):
    """Query a filter (or its artifact) on device from raw host keys.

    ``keys`` may be uint64 fingerprints or raw strings (required for
    learned filters).  ``costs`` optionally supplies per-key costs for the
    WBF query-side k recovery, mirroring ``WeightedBloomFilter.query``.
    """
    if not isinstance(obj, _ArtifactBase):
        obj = obj.to_artifact()
    if isinstance(obj, NgramArtifact):
        raise TypeError("n-gram blocklists are queried with a token batch: "
                        "query(artifact, tokens)")
    u64 = as_u64_keys(keys)
    lo, hi = split_u64(u64)
    kw: dict = {}
    if isinstance(obj, WBFArtifact):
        ks = (ks_for_costs(costs, obj.k_bar, obj.k_max)
              if costs is not None else _wbf_cached_ks(obj, u64))
        kw["ks"] = jnp.asarray(ks, jnp.int32)
    if isinstance(obj, (LearnedArtifact, AdaBFArtifact)):
        from ..core.learned import encode_keys
        strs = as_str_keys(keys)
        if strs is None:
            raise TypeError("learned filters need string keys to featurize")
        kw["bytes_mat"] = encode_keys(strs)
    return query(obj, jnp.asarray(lo), jnp.asarray(hi),
                 use_kernel=use_kernel, interpret=interpret, **kw)
