"""Pure-jnp oracle for the fused two-round HABF query.

Branchless TPU formulation of the paper's query (§III-E): round 1 (H0),
the k-step HashExpressor walk, and round 2 (customized phi) are evaluated
for every key; the result is `r1 | (walk_valid & endbit & r2)`.  The same
32-bit hash value per retrieved hash index drives both the next walk cell
(fastrange to omega) and the round-2 bit probe (fastrange to m), exactly
as on the host."""
from __future__ import annotations

import jax.numpy as jnp

from .. import common


def habf_query_ref(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                   c1, c2, mul, f_c1, f_c2, f_mul, h0_idx,
                   m: int, omega: int, k: int, double_hash: bool = False):
    """Returns (n,) bool membership."""
    # ---- round 1: H0 ------------------------------------------------------
    r1 = jnp.ones(key_lo.shape, jnp.uint32)
    for j in range(k):
        if double_hash:
            hv = common.double_hash_value(key_lo, key_hi, h0_idx[j], c1, c2, mul)
        else:
            hv = common.hash_value(key_lo, key_hi, c1[h0_idx[j]],
                                   c2[h0_idx[j]], mul[h0_idx[j]])
        r1 = r1 & common.probe_bits(words, common.fastrange(hv, m))

    # ---- HashExpressor walk + round 2 --------------------------------------
    cell = common.fastrange(
        common.hash_value(key_lo, key_hi, f_c1[0], f_c2[0], f_mul[0]), omega)
    valid = jnp.ones(key_lo.shape, jnp.uint32)
    r2 = jnp.ones(key_lo.shape, jnp.uint32)
    last_end = jnp.zeros(key_lo.shape, jnp.uint32)
    for step in range(k):
        content = jnp.take(hx_hashidx, cell, axis=0, mode="clip").astype(jnp.int32)
        valid = valid & (content > 0).astype(jnp.uint32)
        hidx = jnp.maximum(content - 1, 0)
        if double_hash:
            hv = common.double_hash_value(key_lo, key_hi, hidx, c1, c2, mul)
        else:
            hv = common.hash_value(key_lo, key_hi,
                                   jnp.take(c1, hidx, mode="clip"),
                                   jnp.take(c2, hidx, mode="clip"),
                                   jnp.take(mul, hidx, mode="clip"))
        r2 = r2 & common.probe_bits(words, common.fastrange(hv, m))
        last_end = jnp.take(hx_endbit, cell, axis=0, mode="clip").astype(jnp.uint32)
        if step + 1 < k:
            cell = common.fastrange(hv, omega)
    return (r1 | (valid & last_end & r2)).astype(jnp.bool_)
