"""jit'd public wrapper for the fused HABF two-round query.

The positional `habf_query` stays as the low-level jit surface; typed
callers should go through `repro.kernels.query(HABFArtifact, ...)`.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import habf_query_pallas
from .ref import habf_query_ref


@partial(jax.jit, static_argnames=("m", "omega", "k", "double_hash",
                                   "use_kernel", "interpret"))
def habf_query(key_lo, key_hi, words, hx_hashidx, hx_endbit, c1, c2, mul,
               f_consts, h0_idx, *, m: int, omega: int, k: int,
               double_hash: bool = False, use_kernel: bool = True,
               interpret: bool | None = None):
    if use_kernel:
        out = habf_query_pallas(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                                c1, c2, mul, f_consts, h0_idx, m, omega, k,
                                double_hash=double_hash, interpret=interpret)
        return out.astype(jnp.bool_)
    return habf_query_ref(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                          c1, c2, mul, f_consts[0], f_consts[1], f_consts[2],
                          h0_idx, m, omega, k, double_hash=double_hash)


def device_tables(habf) -> dict:
    """Deprecated shim: use `habf.to_artifact()` (typed pytree) instead of
    a stringly dict."""
    warnings.warn("kernels.habf_query.device_tables is deprecated; use "
                  "habf.to_artifact()", DeprecationWarning, stacklevel=2)
    a = habf.to_artifact()
    return dict(words=a.words, hx_hashidx=a.hx_hashidx,
                hx_endbit=a.hx_endbit, c1=a.c1, c2=a.c2, mul=a.mul,
                f_consts=a.f_consts, h0_idx=a.h0_idx, m=a.m, omega=a.omega,
                k=a.k, double_hash=a.double_hash)


def habf_query_u64(habf, keys_u64: np.ndarray, use_kernel: bool = True):
    """Deprecated shim: use `repro.kernels.query_keys(habf, keys)`."""
    warnings.warn("habf_query_u64 is deprecated; use "
                  "repro.kernels.query_keys(filter, keys)",
                  DeprecationWarning, stacklevel=2)
    from ..dispatch import query_keys
    return query_keys(habf, keys_u64, use_kernel=use_kernel)
