"""jit'd public wrapper for the fused HABF two-round query."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...core import hashing
from .kernel import habf_query_pallas
from .ref import habf_query_ref


@partial(jax.jit, static_argnames=("m", "omega", "k", "double_hash",
                                   "use_kernel", "interpret"))
def habf_query(key_lo, key_hi, words, hx_hashidx, hx_endbit, c1, c2, mul,
               f_consts, h0_idx, *, m: int, omega: int, k: int,
               double_hash: bool = False, use_kernel: bool = True,
               interpret: bool | None = None):
    if use_kernel:
        out = habf_query_pallas(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                                c1, c2, mul, f_consts, h0_idx, m, omega, k,
                                double_hash=double_hash, interpret=interpret)
        return out.astype(jnp.bool_)
    return habf_query_ref(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                          c1, c2, mul, f_consts[0], f_consts[1], f_consts[2],
                          h0_idx, m, omega, k, double_hash=double_hash)


def device_tables(habf) -> dict:
    """Flatten an HABF object into jit-ready device arrays."""
    bf_t = habf.bf.device_tables()
    hx_t = habf.hx.device_tables()
    f_consts = jnp.stack([jnp.asarray(hx_t["f_c1"]), jnp.asarray(hx_t["f_c2"]),
                          jnp.asarray(hx_t["f_mul"])])  # (3, 1) uint32
    return dict(
        words=jnp.asarray(bf_t["words"]),
        hx_hashidx=jnp.asarray(hx_t["hashidx"]),
        hx_endbit=jnp.asarray(hx_t["endbit"]),
        c1=jnp.asarray(bf_t["c1"]), c2=jnp.asarray(bf_t["c2"]),
        mul=jnp.asarray(bf_t["mul"]), f_consts=f_consts,
        h0_idx=jnp.asarray(bf_t["hash_idx"], jnp.int32),
        m=bf_t["m"], omega=hx_t["omega"], k=hx_t["k"],
        double_hash=bool(hx_t["double_hash"]),
    )


def habf_query_u64(habf, keys_u64: np.ndarray, use_kernel: bool = True):
    """Query a host-built HABF on device; mirrors HABF.query()."""
    t = device_tables(habf)
    lo, hi = hashing.split_u64(keys_u64)
    return habf_query(jnp.asarray(lo), jnp.asarray(hi), t["words"],
                      t["hx_hashidx"], t["hx_endbit"], t["c1"], t["c2"],
                      t["mul"], t["f_consts"], t["h0_idx"], m=t["m"],
                      omega=t["omega"], k=t["k"],
                      double_hash=t["double_hash"], use_kernel=use_kernel)
