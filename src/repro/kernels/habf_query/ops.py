"""jit'd public wrapper for the fused HABF two-round query.

The positional `habf_query` stays as the low-level jit surface; typed
callers should go through `repro.kernels.query(HABFArtifact, ...)`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import habf_query_pallas
from .ref import habf_query_ref


@partial(jax.jit, static_argnames=("m", "omega", "k", "double_hash",
                                   "use_kernel", "interpret"))
def habf_query(key_lo, key_hi, words, hx_hashidx, hx_endbit, c1, c2, mul,
               f_consts, h0_idx, *, m: int, omega: int, k: int,
               double_hash: bool = False, use_kernel: bool = True,
               interpret: bool | None = None):
    if use_kernel:
        out = habf_query_pallas(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                                c1, c2, mul, f_consts, h0_idx, m, omega, k,
                                double_hash=double_hash, interpret=interpret)
        return out.astype(jnp.bool_)
    return habf_query_ref(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                          c1, c2, mul, f_consts[0], f_consts[1], f_consts[2],
                          h0_idx, m, omega, k, double_hash=double_hash)
