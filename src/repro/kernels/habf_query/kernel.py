"""Pallas TPU kernel: fused two-round HABF query.

Both tables (Bloom bit vector + HashExpressor cell arrays) are pinned in
VMEM via full-array BlockSpecs; keys stream through in (8,128) tiles.
The k-step pointer walk is a fixed-trip-count unrolled loop of lane
gathers — no data-dependent control flow (branchless predication instead
of the paper's early exits; see DESIGN.md §3)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import common

BLOCK = 1024
_SUB = 8
_LANE = 128


def _gather(arr, idx):
    return jnp.take(arr, idx.reshape(-1).astype(jnp.int32), axis=0,
                    mode="clip").reshape(idx.shape)


def _kernel(lo_ref, hi_ref, words_ref, hidx_ref, end_ref,
            c1_ref, c2_ref, mul_ref, f_ref, h0_ref, out_ref,
            *, m: int, omega: int, k: int, double_hash: bool):
    lo = lo_ref[...]
    hi = hi_ref[...]
    words = words_ref[...]
    hashidx = hidx_ref[...]
    endbit = end_ref[...]
    c1, c2, mul = c1_ref[...], c2_ref[...], mul_ref[...]
    f_c1, f_c2, f_mul = f_ref[0], f_ref[1], f_ref[2]

    def probe(idx):
        word = _gather(words, idx >> 5)
        return (word >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1)

    # round 1 (H0)
    r1 = jnp.ones(lo.shape, jnp.uint32)
    for j in range(k):
        if double_hash:
            hv = common.double_hash_value(lo, hi, h0_ref[j], c1, c2, mul)
        else:
            hj = h0_ref[j]
            hv = common.hash_value(lo, hi, _gather(c1, jnp.full(lo.shape, hj)),
                                   _gather(c2, jnp.full(lo.shape, hj)),
                                   _gather(mul, jnp.full(lo.shape, hj)))
        r1 = r1 & probe(common.fastrange(hv, m))

    # walk + round 2
    cell = common.fastrange(common.hash_value(lo, hi, f_c1, f_c2, f_mul),
                            omega)
    valid = jnp.ones(lo.shape, jnp.uint32)
    r2 = jnp.ones(lo.shape, jnp.uint32)
    last_end = jnp.zeros(lo.shape, jnp.uint32)
    for step in range(k):
        content = _gather(hashidx, cell).astype(jnp.int32)
        valid = valid & (content > 0).astype(jnp.uint32)
        hidx = jnp.maximum(content - 1, 0)
        if double_hash:
            hv = common.double_hash_value(lo, hi, hidx, c1, c2, mul)
        else:
            hv = common.hash_value(lo, hi, _gather(c1, hidx),
                                   _gather(c2, hidx), _gather(mul, hidx))
        r2 = r2 & probe(common.fastrange(hv, m))
        last_end = _gather(endbit, cell).astype(jnp.uint32)
        if step + 1 < k:
            cell = common.fastrange(hv, omega)
    out_ref[...] = r1 | (valid & last_end & r2)


def habf_query_pallas(key_lo, key_hi, words, hx_hashidx, hx_endbit,
                      c1, c2, mul, f_consts, h0_idx,
                      m: int, omega: int, k: int, double_hash: bool = False,
                      interpret: bool | None = None):
    if interpret is None:
        interpret = common.TPU_INTERPRET
    (lo_p, n) = common.pad_to(key_lo, BLOCK)
    (hi_p, _) = common.pad_to(key_hi, BLOCK)
    nb = lo_p.shape[0] // BLOCK
    lo2 = lo_p.reshape(nb * _SUB, _LANE)
    hi2 = hi_p.reshape(nb * _SUB, _LANE)
    # uint8 tables -> int32 for clean VMEM gathers
    hidx32 = hx_hashidx.astype(jnp.int32)
    end32 = hx_endbit.astype(jnp.int32)

    kern = partial(_kernel, m=m, omega=omega, k=k, double_hash=double_hash)
    full = lambda a: pl.BlockSpec(a.shape, lambda i: tuple(0 for _ in a.shape))
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),
            full(words), full(hidx32), full(end32),
            full(c1), full(c2), full(mul), full(f_consts), full(h0_idx),
        ],
        out_specs=pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * _SUB, _LANE), jnp.uint32),
        interpret=interpret,
    )(lo2, hi2, words, hidx32, end32, c1, c2, mul, f_consts, h0_idx)
    return out.reshape(-1)[:n]
