"""Pure-jnp oracle for the batched Xor-filter query.

The query is 3 salted slot gathers xor'd together and compared against
the key's fingerprint (Graf & Lemire 2020); the per-round key salt is
recomputed from the artifact's static ``seed_round`` exactly as the host
peeler derived it.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import common
from ...core.xor_filter import _SALT_STEP

_MASK64 = 0xFFFFFFFFFFFFFFFF


def xor_salt(seed_round: int) -> tuple[int, int]:
    """Static (lo, hi) uint32 halves of the winning round's key salt."""
    salt = (seed_round * _SALT_STEP) & _MASK64
    return salt & 0xFFFFFFFF, salt >> 32


def xor_query_ref(key_lo, key_hi, table, c1, c2, mul, seg_len: int,
                  fp_bits: int, seed_round: int):
    """key_lo/key_hi: (n,) uint32 halves.  table: (3 * seg_len,) uint32
    fingerprints.  c1/c2/mul: (4,) uint32 — 3 slot hashes + 1 fingerprint
    hash.  Returns (n,) bool."""
    slo, shi = xor_salt(seed_round)
    lo = key_lo ^ jnp.uint32(slo)
    hi = key_hi ^ jnp.uint32(shi)
    got = jnp.zeros(key_lo.shape, jnp.uint32)
    for j in range(3):
        hv = common.hash_value(lo, hi, c1[j], c2[j], mul[j])
        slot = common.fastrange(hv, seg_len) + j * seg_len
        got = got ^ jnp.take(table, slot, axis=0, mode="clip")
    fp = common.hash_value(key_lo, key_hi, c1[3], c2[3], mul[3])
    fp = jnp.maximum(fp & jnp.uint32((1 << fp_bits) - 1), jnp.uint32(1))
    return got == fp
