"""jit'd public wrapper for the Xor-filter query kernel.

The positional `xor_query` is the low-level jit surface; typed callers
should go through `repro.kernels.query(XorArtifact, ...)`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import xor_query_pallas
from .ref import xor_query_ref


@partial(jax.jit, static_argnames=("seg_len", "fp_bits", "seed_round",
                                   "use_kernel", "interpret"))
def xor_query(key_lo, key_hi, table, c1, c2, mul, *, seg_len: int,
              fp_bits: int, seed_round: int, use_kernel: bool = True,
              interpret: bool | None = None):
    if use_kernel:
        out = xor_query_pallas(key_lo, key_hi, table, c1, c2, mul, seg_len,
                               fp_bits, seed_round, interpret=interpret)
        return out.astype(jnp.bool_)
    return xor_query_ref(key_lo, key_hi, table, c1, c2, mul, seg_len,
                         fp_bits, seed_round)
