"""Pallas TPU kernel: fused Xor-filter probe.

Same skeleton as bloom_query: the whole fingerprint table stays resident
in VMEM via a full-array BlockSpec (1.23 bits-per-key tables are far
below the 16 MB budget at paper scales); keys stream HBM->VMEM in
(8, 128) tiles.  The 3 salted slot gathers and the fingerprint compare
fuse into one pass — the salt is static (derived from the artifact's
``seed_round``), so it folds into the hashing constants at trace time.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import common
from .ref import xor_salt

BLOCK = 1024
_SUB = 8
_LANE = 128


def _kernel(lo_ref, hi_ref, table_ref, c1_ref, c2_ref, mul_ref, out_ref,
            *, seg_len: int, fp_bits: int, salt_lo: int, salt_hi: int):
    lo = lo_ref[...]
    hi = hi_ref[...]
    table = table_ref[...]
    slo = lo ^ jnp.uint32(salt_lo)
    shi = hi ^ jnp.uint32(salt_hi)
    got = jnp.zeros(lo.shape, jnp.uint32)
    for j in range(3):
        hv = common.hash_value(slo, shi, c1_ref[j], c2_ref[j], mul_ref[j])
        slot = common.fastrange(hv, seg_len) + jnp.uint32(j * seg_len)
        got = got ^ jnp.take(table, slot.astype(jnp.int32).reshape(-1),
                             axis=0, mode="clip").reshape(slot.shape)
    fp = common.hash_value(lo, hi, c1_ref[3], c2_ref[3], mul_ref[3])
    fp = jnp.maximum(fp & jnp.uint32((1 << fp_bits) - 1), jnp.uint32(1))
    out_ref[...] = (got == fp).astype(jnp.uint32)


def xor_query_pallas(key_lo, key_hi, table, c1, c2, mul, seg_len: int,
                     fp_bits: int, seed_round: int,
                     interpret: bool | None = None):
    """(n,) uint32 key halves -> (n,) uint32 membership flags (0/1)."""
    if interpret is None:
        interpret = common.TPU_INTERPRET
    (lo_p, n) = common.pad_to(key_lo, BLOCK)
    (hi_p, _) = common.pad_to(key_hi, BLOCK)
    nb = lo_p.shape[0] // BLOCK
    lo2 = lo_p.reshape(nb * _SUB, _LANE)
    hi2 = hi_p.reshape(nb * _SUB, _LANE)

    salt_lo, salt_hi = xor_salt(seed_round)
    kern = partial(_kernel, seg_len=seg_len, fp_bits=fp_bits,
                   salt_lo=salt_lo, salt_hi=salt_hi)
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),   # keys lo
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),   # keys hi
            pl.BlockSpec(table.shape, lambda i: (0,)),       # table: VMEM-resident
            pl.BlockSpec(c1.shape, lambda i: (0,)),
            pl.BlockSpec(c2.shape, lambda i: (0,)),
            pl.BlockSpec(mul.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * _SUB, _LANE), jnp.uint32),
        interpret=interpret,
    )(lo2, hi2, table, c1, c2, mul)
    return out.reshape(-1)[:n]
