"""jit'd public wrapper for the Bloom-query kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...core import hashing
from .kernel import bloom_query_pallas
from .ref import bloom_query_ref


@partial(jax.jit, static_argnames=("m", "k", "double_hash", "use_kernel",
                                   "interpret"))
def bloom_query(key_lo, key_hi, words, c1, c2, mul, *, m: int, k: int,
                double_hash: bool = False, use_kernel: bool = True,
                interpret: bool | None = None):
    if use_kernel:
        out = bloom_query_pallas(key_lo, key_hi, words, c1, c2, mul, m, k,
                                 double_hash=double_hash, interpret=interpret)
        return out.astype(jnp.bool_)
    return bloom_query_ref(key_lo, key_hi, words, c1, c2, mul, m, k,
                           double_hash=double_hash)


def bloom_query_u64(bf, keys_u64: np.ndarray, use_kernel: bool = True):
    """Convenience: query a host-side BloomFilter object on device."""
    t = bf.device_tables()
    lo, hi = hashing.split_u64(keys_u64)
    fam_idx = t["hash_idx"]
    dh = bf.__class__.__name__.startswith("DoubleHash")
    c1 = t["c1"] if dh else t["c1"][fam_idx]
    c2 = t["c2"] if dh else t["c2"][fam_idx]
    mul = t["mul"] if dh else t["mul"][fam_idx]
    return bloom_query(jnp.asarray(lo), jnp.asarray(hi),
                       jnp.asarray(t["words"]), jnp.asarray(c1),
                       jnp.asarray(c2), jnp.asarray(mul),
                       m=t["m"], k=len(fam_idx), double_hash=dh,
                       use_kernel=use_kernel)
