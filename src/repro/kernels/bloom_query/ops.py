"""jit'd public wrapper for the Bloom-query kernel.

The positional `bloom_query` stays as the low-level jit surface; typed
callers should go through `repro.kernels.query(BloomArtifact, ...)`.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import bloom_query_pallas
from .ref import bloom_query_ref


@partial(jax.jit, static_argnames=("m", "k", "double_hash", "use_kernel",
                                   "interpret"))
def bloom_query(key_lo, key_hi, words, c1, c2, mul, *, m: int, k: int,
                double_hash: bool = False, use_kernel: bool = True,
                interpret: bool | None = None):
    if use_kernel:
        out = bloom_query_pallas(key_lo, key_hi, words, c1, c2, mul, m, k,
                                 double_hash=double_hash, interpret=interpret)
        return out.astype(jnp.bool_)
    return bloom_query_ref(key_lo, key_hi, words, c1, c2, mul, m, k,
                           double_hash=double_hash)
