"""Pallas TPU kernel: fused k-hash Bloom-filter probe.

TPU adaptation (DESIGN.md §3): the *entire* word-packed bit vector stays
resident in VMEM (paper-default 2 MB filter ≪ 16 MB VMEM) via a
full-array BlockSpec; keys are streamed HBM→VMEM in (8, 128)-aligned
blocks.  All hashing is uint32 VPU arithmetic (no modulo — Lemire
fastrange via 16-bit-limb mulhi); the k probes are unrolled and combined
with a predicated AND, so there is no divergent control flow.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import common

# keys per grid step: one (8, 128) vreg tile times 8 sublanes-rows
BLOCK = 1024
_SUB = 8
_LANE = 128


def _kernel(lo_ref, hi_ref, words_ref, c1_ref, c2_ref, mul_ref, out_ref,
            *, m: int, k: int, double_hash: bool):
    lo = lo_ref[...]
    hi = hi_ref[...]
    words = words_ref[...]
    acc = jnp.ones(lo.shape, jnp.uint32)
    for j in range(k):
        if double_hash:
            hv = common.double_hash_value(lo, hi, j, c1_ref[...], c2_ref[...],
                                          mul_ref[...])
        else:
            hv = common.hash_value(lo, hi, c1_ref[j], c2_ref[j], mul_ref[j])
        idx = common.fastrange(hv, m)
        word = jnp.take(words, (idx >> 5).astype(jnp.int32).reshape(-1),
                        axis=0, mode="clip").reshape(idx.shape)
        acc = acc & ((word >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1))
    out_ref[...] = acc


def bloom_query_pallas(key_lo, key_hi, words, c1, c2, mul, m: int, k: int,
                       double_hash: bool = False,
                       interpret: bool | None = None):
    """(n,) uint32 key halves -> (n,) uint32 membership flags (0/1)."""
    if interpret is None:
        interpret = common.TPU_INTERPRET
    (lo_p, n) = common.pad_to(key_lo, BLOCK)
    (hi_p, _) = common.pad_to(key_hi, BLOCK)
    nb = lo_p.shape[0] // BLOCK
    lo2 = lo_p.reshape(nb * _SUB, _LANE)
    hi2 = hi_p.reshape(nb * _SUB, _LANE)

    grid = (nb,)
    kern = partial(_kernel, m=m, k=k, double_hash=double_hash)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),   # keys lo
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),   # keys hi
            pl.BlockSpec(words.shape, lambda i: (0,)),       # filter: VMEM-resident
            pl.BlockSpec(c1.shape, lambda i: (0,)),
            pl.BlockSpec(c2.shape, lambda i: (0,)),
            pl.BlockSpec(mul.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * _SUB, _LANE), jnp.uint32),
        interpret=interpret,
    )(lo2, hi2, words, c1, c2, mul)
    return out.reshape(-1)[:n]
