"""Pure-jnp oracle for the batched Bloom-filter query (round 1 of HABF)."""
from __future__ import annotations

import jax.numpy as jnp

from .. import common


def bloom_query_ref(key_lo, key_hi, words, c1, c2, mul, m: int, k: int,
                    double_hash: bool = False):
    """key_lo/key_hi: (n,) uint32.  words: (W,) uint32 bit vector.
    c1/c2/mul: (>=k,) uint32 per-hash constants (for double hashing only
    rows 0..1 are used as the two base mixers).  Returns (n,) bool."""
    acc = jnp.ones(key_lo.shape, jnp.uint32)
    for j in range(k):
        if double_hash:
            hv = common.double_hash_value(key_lo, key_hi, j, c1, c2, mul)
        else:
            hv = common.hash_value(key_lo, key_hi, c1[j], c2[j], mul[j])
        idx = common.fastrange(hv, m)
        acc = acc & common.probe_bits(words, idx)
    return acc.astype(jnp.bool_)
