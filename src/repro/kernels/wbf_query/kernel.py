"""Pallas TPU kernel: fused variable-k Weighted-Bloom probe.

Bloom-query skeleton with one extra streamed input: the per-key hash
count ``ks``.  The word-packed table is pinned in VMEM via a full-array
BlockSpec; keys and their ``ks`` stream HBM->VMEM in (8, 128) tiles.  All
``k_max`` probes run unrolled and probe ``j`` is disabled for keys with
``ks <= j`` by predication (``bit | (j >= ks)``) — no divergent control
flow, so skewed ``ks`` batches cost the same as uniform ones.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import common

BLOCK = 1024
_SUB = 8
_LANE = 128


def _kernel(lo_ref, hi_ref, ks_ref, words_ref, c1_ref, c2_ref, mul_ref,
            out_ref, *, m: int, k_max: int):
    lo = lo_ref[...]
    hi = hi_ref[...]
    ks = ks_ref[...]
    words = words_ref[...]
    acc = jnp.ones(lo.shape, jnp.uint32)
    for j in range(k_max):
        hv = common.hash_value(lo, hi, c1_ref[j], c2_ref[j], mul_ref[j])
        idx = common.fastrange(hv, m)
        word = jnp.take(words, (idx >> 5).astype(jnp.int32).reshape(-1),
                        axis=0, mode="clip").reshape(idx.shape)
        bit = (word >> (idx & 31).astype(jnp.uint32)) & jnp.uint32(1)
        acc = acc & (bit | (j >= ks).astype(jnp.uint32))
    out_ref[...] = acc


def wbf_query_pallas(key_lo, key_hi, ks, words, c1, c2, mul, m: int,
                     k_max: int, interpret: bool | None = None):
    """(n,) uint32 key halves + (n,) int32 ks -> (n,) uint32 flags (0/1)."""
    if interpret is None:
        interpret = common.TPU_INTERPRET
    (lo_p, n) = common.pad_to(key_lo, BLOCK)
    (hi_p, _) = common.pad_to(key_hi, BLOCK)
    # pad ks with 0: every probe masked off, so pad lanes trivially pass
    # and are sliced away below
    (ks_p, _) = common.pad_to(ks.astype(jnp.int32), BLOCK)
    nb = lo_p.shape[0] // BLOCK
    lo2 = lo_p.reshape(nb * _SUB, _LANE)
    hi2 = hi_p.reshape(nb * _SUB, _LANE)
    ks2 = ks_p.reshape(nb * _SUB, _LANE)

    kern = partial(_kernel, m=m, k_max=k_max)
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),   # keys lo
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),   # keys hi
            pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),   # per-key ks
            pl.BlockSpec(words.shape, lambda i: (0,)),       # filter: VMEM-resident
            pl.BlockSpec(c1.shape, lambda i: (0,)),
            pl.BlockSpec(c2.shape, lambda i: (0,)),
            pl.BlockSpec(mul.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_SUB, _LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * _SUB, _LANE), jnp.uint32),
        interpret=interpret,
    )(lo2, hi2, ks2, words, c1, c2, mul)
    return out.reshape(-1)[:n]
