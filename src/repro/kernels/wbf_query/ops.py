"""jit'd public wrapper for the variable-k Weighted-Bloom query kernel.

The positional `wbf_query` is the low-level jit surface; typed callers
should go through `repro.kernels.query(WBFArtifact, ...)`.  Ada-BF
artifacts reuse this kernel too: their score-bucketed per-key hash
counts are exactly a WBF ``ks`` vector over a Bloom table.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import wbf_query_pallas
from .ref import wbf_query_ref


@partial(jax.jit, static_argnames=("m", "k_max", "use_kernel", "interpret"))
def wbf_query(key_lo, key_hi, ks, words, c1, c2, mul, *, m: int, k_max: int,
              use_kernel: bool = True, interpret: bool | None = None):
    if use_kernel:
        out = wbf_query_pallas(key_lo, key_hi, ks, words, c1, c2, mul, m,
                               k_max, interpret=interpret)
        return out.astype(jnp.bool_)
    return wbf_query_ref(key_lo, key_hi, ks, words, c1, c2, mul, m, k_max)
