"""Pure-jnp oracle for the batched Weighted-Bloom-filter query.

The WBF probe is a Bloom probe with a *per-key* hash count ``ks`` (Bruck
et al. 2006): all ``k_max`` probes are evaluated branchlessly and probe
``j`` is masked out for keys with ``ks <= j``.  ``ks`` comes from the
query-side cost bucketing (``core.wbf.ks_for_costs``), the artifact's
top-cost k-cache, or the ``k_fallback`` zero-FNR floor — all of which
produce a plain (n,) int32 array, so the probe itself never leaves the
device.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import common


def wbf_query_ref(key_lo, key_hi, ks, words, c1, c2, mul, m: int,
                  k_max: int):
    """key_lo/key_hi: (n,) uint32 halves.  ks: (n,) int per-key probe
    counts (clamped to [1, k_max] by the caller).  words: (W,) uint32 bit
    vector.  c1/c2/mul: (>=k_max,) uint32 constants.  Returns (n,) bool."""
    out = jnp.ones(key_lo.shape, jnp.bool_)
    ks = ks.astype(jnp.int32)
    for j in range(k_max):
        hv = common.hash_value(key_lo, key_hi, c1[j], c2[j], mul[j])
        bit = common.probe_bits(words, common.fastrange(hv, m)) == 1
        out = out & (bit | (j >= ks))
    return out
