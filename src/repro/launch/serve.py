"""End-to-end serving driver — the paper-dictated example (HABF is a
serving-layer data structure): batched requests through prefill + decode
with the HABF admission gate and the n-gram blocklist in the loop.

Scenario: the pod keeps a KV-prefix cache; HABF indexes which prefix
fingerprints are resident.  Negative keys = the observed stream of
missing prefixes; cost(e) = prefix length (re-prefill FLOPs ∝ length) —
the skewed-cost regime of §V-F.  A false positive triggers a wasted cache
probe, so the serving report includes the measured weighted FPR next to
the standard BF alternative at equal memory.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import SpaceBudget, make_filter, weighted_fpr
from ..core.hashing import fingerprint_bytes
from ..kernels.ngram_blocklist.ops import build_blocklist
from ..models.model import Model
from ..runtime.serve_loop import (make_prefill_step, make_decode_step,
                                  admission_probe)


def build_admission_filter(n_cached: int = 5000, n_missing: int = 5000,
                           total_bytes: int = 8192, seed: int = 0):
    """HABF over synthetic prefix fingerprints with length-skewed costs."""
    rng = np.random.default_rng(seed)
    cached = fingerprint_bytes([f"prefix-cached-{i}" for i in range(n_cached)])
    missing = fingerprint_bytes([f"prefix-miss-{i}" for i in range(n_missing)])
    lengths = rng.zipf(2.0, n_missing).clip(1, 32_768).astype(np.float64)
    space = SpaceBudget(total_bytes)
    habf = make_filter("habf", cached, missing, lengths, space=space,
                       seed=seed, k=3)
    bf = make_filter("bloom", cached, space=space)
    stats = {
        "habf_weighted_fpr": weighted_fpr(habf.query(missing), lengths),
        "bf_weighted_fpr": weighted_fpr(bf.query(missing), lengths),
        "zero_fnr": bool(habf.query(cached).all()),
    }
    return habf, cached, missing, lengths, stats


def run(arch: str = "qwen3-0.6b", reduced: bool = True, batch: int = 8,
        prompt_len: int = 64, gen: int = 32, seed: int = 0,
        habf_gate: bool = True, blocklist: bool = True) -> dict:
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)

    habf, cached, missing, lengths, fstats = build_admission_filter(seed=seed)
    gate = habf.to_artifact() if habf_gate else None

    bl_art = None
    if blocklist:
        grams = rng.integers(0, cfg.vocab, (64, 4)).astype(np.int32)
        bl_art = build_blocklist(grams, 1 << 14, k=3)

    n_img = cfg.n_img_tokens if cfg.family == "vlm" else 0
    total_len = prompt_len + n_img + gen + 1
    cache = model.init_cache(batch, total_len)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    if cfg.family == "audio":
        prompt["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_frames, cfg.d_model)), cfg.cdtype)
    if cfg.family == "vlm":
        prompt["img_embeds"] = jnp.asarray(
            rng.normal(size=(batch, n_img, cfg.d_model)), cfg.cdtype)
    if habf_gate:
        # half the batch asks for cached prefixes, half for missing ones
        mix = np.concatenate([cached[:batch // 2],
                              missing[: batch - batch // 2]])
        prompt["prefix_lo"] = jnp.asarray(mix & 0xFFFFFFFF, jnp.uint32)
        prompt["prefix_hi"] = jnp.asarray(mix >> np.uint64(32), jnp.uint32)

    prefill = jax.jit(make_prefill_step(model, admission=gate))
    decode = jax.jit(make_decode_step(model, blocklist=bl_art))

    t0 = time.time()
    out, cache = prefill(params, prompt, cache)
    tok = out["next_token"]
    admitted = np.asarray(out.get("admit", np.ones(batch, bool)))
    window = jnp.zeros((batch, 4), jnp.int32)
    blocked = 0
    toks = [tok]
    for i in range(gen - 1):
        o, cache = decode(params, tok, cache, jnp.int32(prompt_len + n_img + i),
                          window)
        tok = o["next_token"]
        if "blocked" in o:
            blocked += int(np.asarray(o["blocked"]).sum())
            window = o["window"]
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    tokens_out = int(batch * gen)
    return {
        "tokens_per_s": tokens_out / dt,
        "latency_s": dt,
        "admitted": int(admitted.sum()),
        "batch": batch,
        "blocked_ngrams": blocked,
        "filter_stats": fstats,
        "generated": np.stack([np.asarray(t) for t in toks], axis=1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-habf-gate", dest="habf_gate", action="store_false")
    ap.add_argument("--no-blocklist", dest="blocklist", action="store_false")
    args = ap.parse_args()
    out = run(arch=args.arch, reduced=args.reduced, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen,
              habf_gate=args.habf_gate, blocklist=args.blocklist)
    fs = out["filter_stats"]
    print(f"served {out['batch']} requests @ {out['tokens_per_s']:.1f} tok/s; "
          f"admitted {out['admitted']}/{out['batch']}; "
          f"blocked n-grams {out['blocked_ngrams']}")
    print(f"admission filter: HABF wFPR={fs['habf_weighted_fpr']:.2e} vs "
          f"BF wFPR={fs['bf_weighted_fpr']:.2e} (same memory); "
          f"zero-FNR={fs['zero_fnr']}")


if __name__ == "__main__":
    main()
