"""End-to-end serving driver — the paper-dictated example (HABF is a
serving-layer data structure): batched requests through prefill + decode
with the HABF admission gate and the n-gram blocklist in the loop.

Scenario: the pod keeps a KV-prefix cache; HABF indexes which prefix
fingerprints are resident.  Negative keys = the observed stream of
missing prefixes; cost(e) = prefix length (re-prefill FLOPs ∝ length) —
the skewed-cost regime of §V-F.  A false positive triggers a wasted cache
probe, so the serving report includes the measured weighted FPR next to
the standard BF alternative at equal memory.

Both gates are entries in one `FilterBank` (admission + blocklist) and
the canonical `serve_loop.generate` driver does the gating: decode
window width derived from the blocklist's n, window seeded from the
prompt tail, per-filter telemetry in the returned report.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import SpaceBudget, make_filter, weighted_fpr
from ..core.hashing import fingerprint_bytes
from ..kernels.ngram_blocklist.ops import build_blocklist
from ..models.model import Model
from ..runtime.filter_bank import FilterBank
from ..runtime.serve_loop import generate


def build_admission_filter(n_cached: int = 5000, n_missing: int = 5000,
                           total_bytes: int = 8192, seed: int = 0):
    """HABF over synthetic prefix fingerprints with length-skewed costs."""
    rng = np.random.default_rng(seed)
    cached = fingerprint_bytes([f"prefix-cached-{i}" for i in range(n_cached)])
    missing = fingerprint_bytes([f"prefix-miss-{i}" for i in range(n_missing)])
    lengths = rng.zipf(2.0, n_missing).clip(1, 32_768).astype(np.float64)
    space = SpaceBudget(total_bytes)
    habf = make_filter("habf", cached, missing, lengths, space=space,
                       seed=seed, k=3)
    bf = make_filter("bloom", cached, space=space)
    stats = {
        "habf_weighted_fpr": weighted_fpr(habf.query(missing), lengths),
        "bf_weighted_fpr": weighted_fpr(bf.query(missing), lengths),
        "zero_fnr": bool(habf.query(cached).all()),
    }
    return habf, cached, missing, lengths, stats


def run(arch: str = "qwen3-0.6b", reduced: bool = True, batch: int = 8,
        prompt_len: int = 64, gen: int = 32, seed: int = 0,
        habf_gate: bool = True, blocklist: bool = True,
        blocklist_n: int = 4, mesh=None) -> dict:
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)

    # both paper gates are entries in ONE FilterBank: mesh-aware placement
    # + per-filter serving telemetry behind a single dispatcher
    bank = FilterBank(mesh=mesh)
    habf, cached, missing, lengths, fstats = build_admission_filter(seed=seed)
    if habf_gate:
        bank.register("admission", habf)
    if blocklist:
        grams = rng.integers(0, cfg.vocab,
                             (64, blocklist_n)).astype(np.int32)
        bank.register("blocklist", build_blocklist(grams, 1 << 14, k=3))

    n_img = cfg.n_img_tokens if cfg.family == "vlm" else 0
    total_len = prompt_len + n_img + gen + 1
    cache = model.init_cache(batch, total_len)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    if cfg.family == "audio":
        prompt["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.enc_frames, cfg.d_model)), cfg.cdtype)
    if cfg.family == "vlm":
        prompt["img_embeds"] = jnp.asarray(
            rng.normal(size=(batch, n_img, cfg.d_model)), cfg.cdtype)
    if habf_gate:
        # half the batch asks for cached prefixes, half for missing ones
        mix = np.concatenate([cached[:batch // 2],
                              missing[: batch - batch // 2]])
        prompt["prefix_lo"] = jnp.asarray(mix & 0xFFFFFFFF, jnp.uint32)
        prompt["prefix_hi"] = jnp.asarray(mix >> np.uint64(32), jnp.uint32)

    # the canonical driver now does the gating: the decode window width is
    # derived from the registered blocklist's n (was hardcoded to 4) and
    # seeded from the prompt tail, so no zero-padded window is ever probed
    t0 = time.time()
    toks, cache, rep = generate(model, params, prompt, cache, gen, bank=bank)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    admitted = rep.get("admit", np.ones(batch, bool))
    tokens_out = int(batch * gen)
    telemetry = bank.telemetry()
    bank.close()      # unhook from kernels.dispatch; snapshot taken above
    return {
        "tokens_per_s": tokens_out / dt,
        "latency_s": dt,
        "admitted": int(admitted.sum()),
        "batch": batch,
        "blocked_ngrams": rep.get("blocked_ngrams", 0),
        "filter_stats": fstats,
        "bank_telemetry": telemetry,
        "generated": np.asarray(toks),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-habf-gate", dest="habf_gate", action="store_false")
    ap.add_argument("--no-blocklist", dest="blocklist", action="store_false")
    ap.add_argument("--blocklist-n", type=int, default=4)
    args = ap.parse_args()
    out = run(arch=args.arch, reduced=args.reduced, batch=args.batch,
              prompt_len=args.prompt_len, gen=args.gen,
              habf_gate=args.habf_gate, blocklist=args.blocklist,
              blocklist_n=args.blocklist_n)
    fs = out["filter_stats"]
    print(f"served {out['batch']} requests @ {out['tokens_per_s']:.1f} tok/s; "
          f"admitted {out['admitted']}/{out['batch']}; "
          f"blocked n-grams {out['blocked_ngrams']}")
    print(f"admission filter: HABF wFPR={fs['habf_weighted_fpr']:.2e} vs "
          f"BF wFPR={fs['bf_weighted_fpr']:.2e} (same memory); "
          f"zero-FNR={fs['zero_fnr']}")
    for name, t in out["bank_telemetry"].items():
        print(f"bank[{name}]: {t['kind']} v{t['version']} {t['bytes']}B, "
              f"{t['keys']} keys probed, hit_rate={t['hit_rate']:.3f}, "
              f"est_fp_cost={t['est_fp_cost']:.3g}")


if __name__ == "__main__":
    main()
