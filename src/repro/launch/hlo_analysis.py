"""Post-SPMD HLO analysis for the roofline (§Roofline in EXPERIMENTS.md).

XLA's HloCostAnalysis counts while-loop bodies ONCE, so a scanned-layers
model under-reports FLOPs by ~n_layers.  This module parses the compiled
HLO text and:

  * extracts exact trip counts from `backend_config={"known_trip_count"..}`
    on while ops,
  * propagates execution multipliers through the computation call graph
    (while body x trip, conditional branches, fusion bodies),
  * sums dot FLOPs (2 * prod(out) * prod(contracting dims)) per-device,
  * sums an HBM-traffic proxy (operand + output bytes of every
    non-fused-context op — fusion internals don't touch HBM),
  * sums collective bytes by kind (output-size proxy for link traffic).

All numbers are PER DEVICE (the HLO is the per-partition module).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "s4": 1, "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128|token)\[([0-9,]*)\]")

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                    r"([a-z][a-z0-9\-]*)\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes_in(type_str: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        d = [int(x) for x in dims.split(",") if x]
        out.append((dt, d))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    kind: str
    type_str: str
    rest: str           # text after the op name (operands + attrs)
    out_bytes: int = 0


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # %name -> shapes list
    producers: dict = field(default_factory=dict)  # %name -> op kind


def parse_module(hlo: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in hlo.splitlines():
        s = raw.rstrip()
        st = s.strip()
        if st.startswith("ENTRY"):
            name = st.split("(")[0].replace("ENTRY", "").strip().lstrip("%")
            cur = comps.setdefault(name, Computation(name))
            entry = name
            continue
        if st.endswith("{") and "(" in st and "=" not in st.split("(")[0]:
            name = st.split("(")[0].strip().lstrip("%").split()[-1]
            cur = comps.setdefault(name, Computation(name))
            continue
        if cur is None:
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, type_str, kind = m.group(1), m.group(2), m.group(3)
        shapes = _shapes_in(type_str)
        cur.symbols[name] = shapes
        rest = s[m.end():]
        cur.producers[name] = kind
        cur.ops.append(Op(name=name, kind=kind, type_str=type_str, rest=rest,
                          out_bytes=_nbytes(shapes)))
    return comps, entry


def _trip_count(rest: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', rest)
    return int(m.group(1)) if m else 1


def _called(rest: str, keys=("body", "condition", "to_apply", "calls")):
    out = []
    for key in keys:
        for m in re.finditer(rf"{key}=%?([\w\.\-]+)", rest):
            out.append((key, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", rest)
    if m:
        for b in m.group(1).split(","):
            out.append(("branch", b.strip().lstrip("%")))
    return out


def _multipliers(comps, entry):
    """(mult, fused_context) per computation, propagated from entry."""
    mult: dict[str, float] = {entry: 1.0}
    fused: dict[str, bool] = {entry: False}
    # topological-ish propagation: iterate until fixpoint (call DAG, small)
    for _ in range(64):
        changed = False
        for cname, comp in comps.items():
            m0 = mult.get(cname)
            if m0 is None:
                continue
            f0 = fused.get(cname, False)
            for op in comp.ops:
                if op.kind == "while":
                    t = _trip_count(op.rest)
                    for key, callee in _called(op.rest, ("body", "condition")):
                        add = m0 * (t if key == "body" else t + 1)
                        if mult.get(callee, 0) < add:
                            mult[callee] = add
                            fused[callee] = f0
                            changed = True
                elif op.kind in ("fusion",):
                    for _, callee in _called(op.rest, ("calls",)):
                        if mult.get(callee, 0) < m0:
                            mult[callee] = m0
                            fused[callee] = True
                            changed = True
                elif op.kind in ("conditional", "call", "custom-call",
                                 "async-start"):
                    for _, callee in _called(op.rest,
                                             ("branch", "to_apply", "calls")):
                        if mult.get(callee, 0) < m0:
                            mult[callee] = m0
                            fused[callee] = f0
                            changed = True
                # reduce/map/sort to_apply bodies: scalar — ignored
        if not changed:
            break
    return mult, fused


def _dot_flops(comp: Computation, op: Op) -> float:
    out_elems = 1
    for dt, dims in _shapes_in(op.type_str):
        for d in dims:
            out_elems *= d
    ops_m = re.findall(r"%([\w\.\-]+)", op.rest.split(")", 1)[0])
    lhs = comp.symbols.get(ops_m[0]) if ops_m else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contract = 1
    if lhs and m:
        dims = lhs[0][1]
        for i in m.group(1).split(","):
            if i:
                contract *= dims[int(i)]
    return 2.0 * out_elems * contract


def _operand_bytes(comp: Computation, op: Op) -> int:
    args = op.rest.split(")", 1)[0]
    total = 0
    for name in re.findall(r"%([\w\.\-]+)", args):
        shapes = comp.symbols.get(name)
        if shapes:
            total += _nbytes(shapes)
    return total


def _operand_n_bytes(comp: Computation, op: Op, n: int) -> int:
    """Bytes of the n-th operand (0-based); 0 if unresolvable."""
    args = op.rest.split(")", 1)[0]
    names = re.findall(r"%([\w\.\-]+)", args)
    if n < len(names):
        shapes = comp.symbols.get(names[n])
        if shapes:
            return _nbytes(shapes)
    return 0


# HBM-traffic proxy: the CPU backend fuses almost nothing, so counting
# every op's operands+outputs massively overestimates what a TPU (which
# fuses elementwise chains into its matmul/reduce consumers) would move.
# We count only ops that are real HBM data movement on TPU; elementwise /
# broadcast / convert / compare / select chains are treated as fused.
# "copy" excluded: XLA:CPU layout assignment emits several copies of the
# same tensor between einsum forms; TPU fuses transposes into consumers.
_BYTES_OPS = {"dot", "convolution", "gather", "scatter", "dynamic-slice",
              "dynamic-update-slice", "reduce", "reduce-window", "sort",
              "concatenate", "cholesky", "triangular-solve", "fft", "rng"}


def analyze(hlo: str) -> dict:
    comps, entry = parse_module(hlo)
    mult, fused = _multipliers(comps, entry)
    flops = 0.0
    hbm_bytes = 0.0
    coll: dict[str, dict] = {}
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(comp, op)
            kind = next((c for c in COLLECTIVES
                         if op.kind == c or op.kind.startswith(c + "-")), None)
            if kind and "done" not in op.kind:
                d = coll.setdefault(kind, {"count": 0, "bytes": 0.0})
                d["count"] += int(m)
                b = op.out_bytes
                # XLA:CPU promotes bf16 all-reduces to f32 (no native bf16
                # summation on CPU), and hoists bf16->f32 converts ahead of
                # gathers; TPU keeps bf16 on the wire.  Count such
                # collectives at their pre-promotion width.
                if "promoted" in op.rest:
                    b //= 2
                else:
                    args = re.findall(r"%([\w\.\-]+)",
                                      op.rest.split(")", 1)[0])
                    if args and (comp.producers.get(args[0]) == "convert"
                                 or "convert" in args[0]):
                        b //= 2
                d["bytes"] += m * b
            if op.kind == "dynamic-slice":
                # in-place slice read: moved bytes = 2 x slice, not operand
                hbm_bytes += m * 2 * op.out_bytes
            elif op.kind == "dynamic-update-slice":
                # in-place update: only the update slice is read + written
                hbm_bytes += m * 2 * _operand_n_bytes(comp, op, 1)
            elif op.kind in _BYTES_OPS:
                hbm_bytes += m * (op.out_bytes + _operand_bytes(comp, op))
            elif kind:
                hbm_bytes += m * 2 * op.out_bytes
    return {"flops": flops, "hbm_bytes": hbm_bytes, "collectives": coll,
            "n_computations": len(comps)}
