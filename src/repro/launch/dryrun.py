import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST be the first two lines — before ANY other import (jax locks the
# device count on first init).  Deliberately NOT set globally: smoke tests
# and benchmarks see 1 device.

# Multi-pod dry-run: lower + compile every (architecture x input shape)
# cell against ShapeDtypeStruct inputs on the production mesh, and record
# memory_analysis / cost_analysis / the collective-op table for §Roofline.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--both-meshes]

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, SHAPES, accum_for
from ..configs.base import ACCUM_STEPS
from ..models.model import Model
from ..runtime import sharding as sh
from ..runtime.train_loop import (make_train_step, make_optimizer,
                                  param_shardings, opt_state_shardings,
                                  batch_shardings, metrics_shardings)
from ..runtime.serve_loop import make_prefill_step, make_decode_step
from .mesh import make_production_mesh
from . import hlo_analysis

# ---- per-(arch, shape) microbatch accumulation (activation fitting) --------
ACCUM_STEPS.update({
    ("llama3-405b", "train_4k"): 16,
    ("llama4-maverick-400b-a17b", "train_4k"): 16,
    ("mistral-nemo-12b", "train_4k"): 8,
    ("llava-next-mistral-7b", "train_4k"): 8,
    ("deepseek-v2-lite-16b", "train_4k"): 8,
    ("mistral-nemo-12b", "prefill_32k"): 1,
})

# 400B-class train cells use Adafactor (factored second moments) — the
# AdamW variant exceeds the 16 GB budget (peak 17.7 GiB; §Perf A7)
OPT_KIND = {
    ("llama3-405b", "train_4k"): "adafactor",
    ("llama4-maverick-400b-a17b", "train_4k"): "adafactor",
}

# long_500k requires sub-quadratic sequence mixing (assignment): skipped for
# pure full-attention archs, recorded as such (DESIGN.md §6).
def runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return ARCHS[arch].sub_quadratic
    return True


def _build_gate_blocklist():
    """2 MB Bloom blocklist (paper-default size) for the fused decode gate."""
    import numpy as np
    from ..kernels.artifacts import NgramArtifact
    from ..core.bloom import BloomFilter
    rng = np.random.default_rng(0)
    bf = BloomFilter(2 * 1024 * 1024 * 8, k=3)
    bf.insert(rng.integers(0, 1 << 63, 100_000).astype(np.uint64))
    return NgramArtifact.from_filter(bf, n=4)


def tree_bytes(tree) -> int:
    return sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             habf_gate: bool = False, rules=None, accum: int | None = None,
             opt_kind: str | None = None) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind, "habf_gate": habf_gate}

    if rules is None and shape.kind in ("decode", "prefill"):
        rules = dict(sh.DECODE_RULES)  # split-KV: cache seq over `model`
    with sh.use_mesh(mesh, rules):
        pshapes, pspecs = model.abstract_init()
        p_sh = param_shardings(mesh, pspecs, rules, shapes=pshapes)
        if cfg.fsdp:
            from ..runtime.train_loop import fsdp_shardings
            p_sh = fsdp_shardings(mesh, p_sh, pshapes)
            rec["fsdp"] = True
        if shape.kind == "train":
            kind = opt_kind or OPT_KIND.get((arch, shape_name), "adamw")
            opt = make_optimizer(cfg, kind=kind)
            rec["optimizer"] = kind
            acc = accum or accum_for(arch, shape_name)
            import jax.numpy as _jnp
            adt = (_jnp.bfloat16 if cfg.opt_state_dtype == "bfloat16"
                   else _jnp.float32)
            step = make_train_step(model, opt, accum=acc, accum_dtype=adt)
            rec["accum_dtype"] = str(_jnp.dtype(adt))
            o_shapes = jax.eval_shape(opt.init, pshapes)
            o_sh = opt_state_shardings(mesh, opt, pshapes, pspecs,
                                       zero1=True, rules=rules, p_sh=p_sh)
            ispecs = model.input_specs(shape)["batch"]
            b_sh = batch_shardings(mesh, ispecs, rules)
            rec["accum"] = acc
            jitted = jax.jit(step,
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh,
                                            metrics_shardings(mesh)))
            lowered = jitted.lower(pshapes, o_shapes, ispecs)
            static_args = (pshapes, o_shapes, ispecs)
            static_sh = (p_sh, o_sh, b_sh)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            specs = model.input_specs(shape)
            c_sh = sh.tree_shardings(mesh, model.cache_specs(), rules,
                                     shapes=specs["cache"])
            b_sh = batch_shardings(mesh, specs["batch"], rules)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh))
            lowered = jitted.lower(pshapes, specs["batch"], specs["cache"])
            static_args = (pshapes, specs["batch"], specs["cache"])
            static_sh = (p_sh, b_sh, c_sh)
        else:  # decode
            specs = model.input_specs(shape)
            c_sh = sh.tree_shardings(mesh, model.cache_specs(), rules,
                                     shapes=specs["cache"])
            tok_sh = sh.spec_for(mesh, dict(sh.DEFAULT_RULES, **(rules or {})),
                                 ("batch",), shape=specs["tokens"].shape)
            pos_sh = sh.spec_for(mesh, sh.DEFAULT_RULES, ())
            if habf_gate:
                # fuse the paper's filters into the lowered decode step:
                # n-gram blocklist probe + (replicated, VMEM-scale) tables
                bl = _build_gate_blocklist()
                step = make_decode_step(model, blocklist=bl)
                B = specs["tokens"].shape[0]
                win = jax.ShapeDtypeStruct((B, bl.n), jnp.int32)
                win_sh = sh.spec_for(mesh, sh.DEFAULT_RULES, ("batch", None),
                                     shape=win.shape)
                jitted = jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh,
                                                     pos_sh, win_sh))
                lowered = jitted.lower(pshapes, specs["tokens"],
                                       specs["cache"], specs["pos"], win)
                static_args = (pshapes, specs["tokens"], specs["cache"],
                               specs["pos"], win)
                static_sh = (p_sh, tok_sh, c_sh, pos_sh, win_sh)
            else:
                step = make_decode_step(model)
                jitted = jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh,
                                                     pos_sh))
                lowered = jitted.lower(pshapes, specs["tokens"],
                                       specs["cache"], specs["pos"])
                static_args = (pshapes, specs["tokens"], specs["cache"],
                               specs["pos"])
                static_sh = (p_sh, tok_sh, c_sh, pos_sh)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        print(f"  memory_analysis: {ma}", flush=True)   # proves it fits
        if ma is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "peak_memory_in_bytes"):
                v = getattr(ma, attr, None)
                if v is not None:
                    rec[attr] = int(v)
        ca = compiled.cost_analysis()
        # jax API drift: cost_analysis() used to return a list of one dict
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        print(f"  cost_analysis: flops={ca.get('flops')} "
              f"bytes={ca.get('bytes accessed')}", flush=True)
        if ca:
            # NOTE: XLA counts while bodies once — kept for reference only;
            # the roofline uses the trip-count-scaled analyzer below.
            rec["xla_cost_flops"] = float(ca.get("flops", 0.0))
            rec["xla_cost_bytes"] = float(ca.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        an = hlo_analysis.analyze(hlo)
        rec["hlo_flops_per_device"] = an["flops"]
        rec["hlo_bytes_per_device"] = an["hbm_bytes"]
        rec["collectives"] = an["collectives"]
        rec["_hlo_text"] = hlo  # popped + dumped compressed by the caller
        # exact per-device argument residency from shardings
        rec["args_bytes_per_device"] = sum(
            _leaf_bytes_per_device(a, s) for a, s in zip(static_args, static_sh))
        pc = cfg.param_counts()
        rec["params_total"] = pc["total"]
        rec["params_active"] = pc["active"]
        rec["n_devices"] = mesh.devices.size
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def _leaf_bytes_per_device(tree, shardings) -> int:
    leaves = jax.tree.leaves(tree)
    shs = jax.tree.leaves(shardings,
                          is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    if len(shs) == 1 and len(leaves) > 1:
        shs = shs * len(leaves)
    total = 0
    for l, s in zip(leaves, shs):
        try:
            shard_shape = s.shard_shape(tuple(l.shape))
            total += int(np.prod(shard_shape)) * jnp.dtype(l.dtype).itemsize
        except Exception:
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--habf-gate", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                if runnable(a, s):
                    cells.append((a, s))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    out_dir = Path(args.out)
    ok = fail = 0
    for multi_pod in meshes:
        sub = out_dir / ("2x16x16" if multi_pod else "16x16")
        sub.mkdir(parents=True, exist_ok=True)
        for arch, shape in cells:
            path = sub / f"{arch}__{shape}.json"
            if args.skip_existing and path.exists():
                ok += 1
                continue
            print(f"[dryrun] {arch} x {shape} mesh="
                  f"{'2x16x16' if multi_pod else '16x16'}", flush=True)
            try:
                rec = run_cell(arch, shape, multi_pod=multi_pod,
                               habf_gate=args.habf_gate)
                hlo = rec.pop("_hlo_text", None)
                if hlo is not None:
                    import zstandard
                    (sub / f"{arch}__{shape}.hlo.zst").write_bytes(
                        zstandard.ZstdCompressor(level=9).compress(
                            hlo.encode()))
                path.write_text(json.dumps(rec, indent=1))
                print(f"  ok: compile={rec['compile_s']}s "
                      f"flops={rec.get('hlo_flops_per_device', 0):.3g} "
                      f"coll={sum(d['bytes'] for d in rec['collectives'].values()):.3g}B",
                      flush=True)
                ok += 1
            except Exception as e:
                fail += 1
                err = {"arch": arch, "shape": shape, "error": str(e),
                       "traceback": traceback.format_exc()[-3000:]}
                (sub / f"{arch}__{shape}.FAILED.json").write_text(
                    json.dumps(err, indent=1))
                print(f"  FAILED: {e}", flush=True)
    print(f"[dryrun] done: {ok} ok, {fail} failed", flush=True)
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
