"""Production mesh builders.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e pod); multi_pod stacks 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(n: int | None = None):
    """Small mesh over however many (host) devices exist — for tests."""
    n = n or len(jax.devices())
    d = max(1, n // 2)
    m = n // d
    return jax.make_mesh((d, m), ("data", "model"))
