"""Recompute hlo_flops/bytes/collectives for existing dry-run records from
their compressed HLO dumps (analyzer iterations don't need recompiles).

  PYTHONPATH=src python -m repro.launch.reanalyze [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import zstandard

from . import hlo_analysis


def reanalyze_dir(d: Path) -> int:
    n = 0
    for j in sorted(d.glob("*.json")):
        if "FAILED" in j.name:
            continue
        z = j.with_suffix("").with_suffix("")  # strip .json
        z = d / (j.name[: -len(".json")] + ".hlo.zst")
        if not z.exists():
            continue
        hlo = zstandard.ZstdDecompressor().decompress(z.read_bytes()).decode()
        an = hlo_analysis.analyze(hlo)
        rec = json.loads(j.read_text())
        rec["hlo_flops_per_device"] = an["flops"]
        rec["hlo_bytes_per_device"] = an["hbm_bytes"]
        rec["collectives"] = an["collectives"]
        j.write_text(json.dumps(rec, indent=1))
        n += 1
    return n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    total = 0
    for sub in Path(args.dir).iterdir():
        if sub.is_dir():
            total += reanalyze_dir(sub)
    print(f"reanalyzed {total} records")


if __name__ == "__main__":
    main()
