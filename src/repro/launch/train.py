"""End-to-end training driver (CPU-runnable on reduced configs; the same
code path the dry-run lowers at production scale).

Wires together every substrate: config registry -> model zoo -> data
pipeline (HABF dedup) -> AdamW (+accum) -> checkpointing -> fault-tolerant
supervisor -> logical-axis sharding on whatever mesh exists.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models.model import Model
from ..optimizer.adamw import AdamW, warmup_cosine
from ..checkpoint.checkpointer import Checkpointer
from ..data.pipeline import DataPipeline, PipelineConfig, build_dedup_filter
from ..runtime import sharding as sh
from ..runtime.train_loop import make_train_step
from ..runtime.fault_tolerance import TrainSupervisor
from .mesh import make_host_mesh


def run(arch: str, reduced: bool = True, steps: int = 100, batch: int = 8,
        seq: int = 128, lr: float = 3e-3, accum: int = 1,
        ckpt_dir: str | None = None, resume: bool = False,
        save_every: int = 50, dedup: bool = True, seed: int = 0,
        log_every: int = 10, use_mesh: bool = True) -> dict:
    cfg = get_config(arch, reduced=reduced)
    model = Model(cfg)
    opt = AdamW(lr=warmup_cosine(lr, warmup=max(1, steps // 10), total=steps),
                weight_decay=0.1)

    dedup_filter = None
    if dedup:
        rng = np.random.default_rng(seed)
        dups = rng.integers(0, 1 << 40, 2000).astype(np.uint64)
        clean = rng.integers(1 << 41, 1 << 42, 4000).astype(np.uint64)
        dedup_filter = build_dedup_filter(dups, clean, total_bytes=1 << 16)

    pipe = DataPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=seq,
                                       global_batch=batch, seed=seed),
                        dedup=dedup_filter)

    params, specs = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    train_step = make_train_step(model, opt, accum=accum)

    mesh_ctx = None
    if use_mesh and len(jax.devices()) > 1:
        mesh = make_host_mesh()
        mesh_ctx = sh.use_mesh(mesh)
        mesh_ctx.__enter__()
    step_jit = jax.jit(train_step)

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt and resume and ckpt.latest_step() is not None:
        (params, opt_state), man = ckpt.restore((params, opt_state))
        start = man["step"]
        pipe.step = man["aux"].get("data_step", start)

    losses = []
    t0 = time.time()

    def one_step(state, step):
        params, opt_state = state
        b = pipe.batch_at(pipe.step)
        pipe.step += 1
        params, opt_state, metrics = step_jit(
            params, opt_state, {"tokens": jnp.asarray(b["tokens"]),
                                "labels": jnp.asarray(b["labels"])})
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        return params, opt_state

    if ckpt:
        sup = TrainSupervisor(ckpt, save_every=save_every)

        def restore_fn(_):
            st, man = ckpt.restore((params, opt_state))
            pipe.step = man["aux"].get("data_step", man["step"])
            return st, man["step"]

        state = sup.run(state=(params, opt_state), step_fn=one_step,
                        n_steps=steps, restore_fn=restore_fn,
                        save_aux_fn=lambda s: {"data_step": pipe.step},
                        start_step=start)
        params, opt_state = state
        report = sup.report
    else:
        state = (params, opt_state)
        for s in range(start, steps):
            state = one_step(state, s)
        params, opt_state = state
        report = None

    if mesh_ctx is not None:
        mesh_ctx.__exit__(None, None, None)
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "skipped_docs": pipe.skipped,
            "report": report.__dict__ if report else None,
            "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-dedup", dest="dedup", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run(arch=args.arch, reduced=args.reduced, steps=args.steps,
              batch=args.batch, seq=args.seq, lr=args.lr, accum=args.accum,
              ckpt_dir=args.ckpt_dir, resume=args.resume, dedup=args.dedup,
              seed=args.seed)
    print(f"final loss {out['final_loss']:.4f}; "
          f"dedup skipped {out['skipped_docs']} docs")


if __name__ == "__main__":
    main()
