"""Deterministic synthetic data pipeline with HABF-based dedup.

Paper integration (DESIGN.md §2): every document carries a 64-bit
fingerprint; an HABF built from (known duplicates = positive keys,
sampled clean docs = negative keys, cost = document length) filters the
stream.  A false positive (clean doc wrongly skipped) costs its tokens —
the weighted-FPR objective — while true duplicates never slip through
(zero FNR).

Production concerns implemented:
  * fully deterministic given (seed, step): resumable from a checkpointed
    step counter (no stream state to persist);
  * per-host sharding: each host materializes only its batch slice;
  * background prefetch thread with a bounded queue;
  * duplicate injection knob for testing dedup behaviour.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from ..core.api import Filter, SpaceBudget, make_filter
from ..core.hashing import hash_value_np, fastrange_np


def _doc_tokens(doc_ids: np.ndarray, seq_len: int, vocab: int) -> np.ndarray:
    """(n,) doc ids -> (n, seq_len) deterministic tokens.  Token ids are
    power-law skewed (u^3 mapping) so the stream has learnable unigram
    structure — a uniform stream would start at the optimal loss."""
    pos = np.arange(seq_len, dtype=np.uint64)[None, :]
    base = doc_ids.astype(np.uint64)[:, None]
    hv = hash_value_np((base << np.uint64(20)) ^ pos, 2)
    u = hv.astype(np.float64) / 2.0 ** 32
    return np.minimum((u ** 3 * vocab).astype(np.int32), vocab - 1)


def doc_fingerprints(doc_ids: np.ndarray) -> np.ndarray:
    a = hash_value_np(doc_ids.astype(np.uint64), 3).astype(np.uint64)
    b = hash_value_np(doc_ids.astype(np.uint64), 4).astype(np.uint64)
    return (a << np.uint64(32)) | b


@dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    dup_fraction: float = 0.0     # injected duplicate rate (testing/dedup)
    prefetch: int = 2


class DataPipeline:
    """Deterministic, resumable, dedup-filtered token stream."""

    def __init__(self, cfg: PipelineConfig, dedup: Filter | None = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.dedup = dedup
        self.step = int(start_step)
        self.skipped = 0
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # ---- deterministic batch synthesis ------------------------------------
    def _doc_ids_for(self, step: int) -> np.ndarray:
        c = self.cfg
        per_host = c.global_batch // c.n_hosts
        base = (np.uint64(step) * np.uint64(c.global_batch)
                + np.uint64(c.host_id * per_host)
                + np.uint64(c.seed) * np.uint64(1 << 40))
        ids = base + np.arange(per_host, dtype=np.uint64)
        if c.dup_fraction > 0:
            rng = np.random.default_rng(c.seed ^ step)
            dup = rng.random(per_host) < c.dup_fraction
            ids = np.where(dup, ids % np.uint64(max(1, c.global_batch)), ids)
        return ids

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        ids = self._doc_ids_for(step)
        if self.dedup is not None:
            fps = doc_fingerprints(ids)
            is_dup = self.dedup.query(fps)
            self.skipped += int(is_dup.sum())
            # replace filtered docs with fresh ids from a disjoint range
            repl = ids + np.uint64(1 << 60)
            ids = np.where(is_dup, repl, ids)
        tokens = _doc_tokens(ids, c.seq_len + 1, c.vocab)
        return {"tokens": tokens[:, :-1],
                "labels": tokens[:, 1:].copy(),
                "doc_ids": ids}

    # ---- iteration / prefetch -----------------------------------------------
    def __next__(self) -> dict:
        if self._thread is None:
            b = self.batch_at(self.step)
            self.step += 1
            return b
        return self._q.get()

    def start_prefetch(self):
        def worker():
            while not self._stop.is_set():
                b = self.batch_at(self.step)
                self.step += 1
                self._q.put(b)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            while not self._q.empty():
                self._q.get_nowait()

    # ---- checkpoint integration ----------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "skipped": self.skipped}

    @classmethod
    def from_state(cls, cfg: PipelineConfig, state: dict,
                   dedup: Filter | None = None) -> "DataPipeline":
        return cls(cfg, dedup=dedup, start_step=state["step"])


def build_dedup_filter(known_dup_ids: np.ndarray, clean_sample_ids: np.ndarray,
                       total_bytes: int = 1 << 20, seed: int = 0,
                       kind: str = "habf") -> Filter:
    """Dedup gate over document fingerprints; any registered filter works
    (HABF default: zero FNR on known duplicates, cost-weighted FPs).  Cost
    of a clean doc = its length proxy (uniform here; hook for
    length-weighted costs)."""
    pos = doc_fingerprints(np.asarray(known_dup_ids, np.uint64))
    neg = doc_fingerprints(np.asarray(clean_sample_ids, np.uint64))
    return make_filter(kind, pos, neg, space=SpaceBudget(total_bytes),
                       seed=seed)
