"""Model zoo: pure-JAX implementations of the assigned architectures."""
from .model import Model
from . import layers, transformer, moe, mla, ssm, hybrid, encdec, vlm

__all__ = ["Model", "layers", "transformer", "moe", "mla", "ssm", "hybrid",
           "encdec", "vlm"]
