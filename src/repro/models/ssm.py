"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Train/prefill use the chunked block decomposition: quadratic attention-like
intra-chunk term + inter-chunk recurrence carried by lax.scan (state
(B, H, P, N)).  Decode is the O(1) single-step recurrence — this is what
makes the `long_500k` cell sub-quadratic (DESIGN.md §6).

TPU adaptation: chunk length defaults to 256 so the intra-chunk (cl, cl)
kernels are MXU-shaped; the depthwise causal conv is unrolled into k
static shifts (no conv primitive needed on the VPU path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import shard
from .layers import ParamBuilder, rmsnorm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.headdim
    d_conv = d_in + 2 * s.state
    return d_in, nh, d_conv


def init_mamba(b: ParamBuilder, cfg: ModelConfig, L: int, prefix: str = "ssm"):
    s = cfg.ssm
    d_in, nh, d_conv = _dims(cfg)
    D = cfg.d_model
    sb = b.sub(prefix)
    sb.make("in_proj", (L, D, 2 * d_in + 2 * s.state + nh),
            ("layers", "d_model", "ssm_heads"))
    sb.make("conv_w", (L, s.conv, d_conv), ("layers", "conv", "ssm_heads"))
    sb.make("conv_b", (L, d_conv), ("layers", "ssm_heads"), init="zeros")
    sb.make("A_log", (L, nh), ("layers", "ssm_heads"), init="zeros")
    sb.make("D_skip", (L, nh), ("layers", "ssm_heads"), init="ones")
    sb.make("dt_bias", (L, nh), ("layers", "ssm_heads"), init="zeros")
    sb.make("norm", (L, d_in), ("layers", "ssm_heads"), init="ones")
    sb.make("out_proj", (L, d_in, D), ("layers", "ssm_heads", "d_model"))


def _split_proj(cfg, zxbcdt):
    s = cfg.ssm
    d_in, nh, _ = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: 2 * d_in + 2 * s.state]
    dt = zxbcdt[..., 2 * d_in + 2 * s.state:]
    return z, xbc, dt


def _conv_causal(xbc, w, bias):
    """Depthwise causal conv via unrolled static shifts."""
    k = w.shape[0]
    T = xbc.shape[1]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = bias
    for i in range(k):
        out = out + pad[:, i: i + T, :] * w[i]
    return out


def _ssd_scan(cfg, xh, dt, A, Bm, Cm, state0=None):
    """Chunked SSD.  xh: (B,T,H,P), dt: (B,T,H), A: (H,), Bm/Cm: (B,T,N).
    Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    s = cfg.ssm
    B, T, H, P = xh.shape
    N = Bm.shape[-1]
    cl = s.chunk if T % s.chunk == 0 else T
    nc = T // cl
    f32 = jnp.float32

    xc = xh.reshape(B, nc, cl, H, P)
    dtc = dt.reshape(B, nc, cl, H).astype(f32)
    Bc = Bm.reshape(B, nc, cl, N)
    Cc = Cm.reshape(B, nc, cl, N)
    if state0 is None:
        state0 = jnp.zeros((B, H, P, N), f32)
    tri = jnp.tril(jnp.ones((cl, cl), bool))

    def body(state, inp):
        x_c, dt_c, b_c, c_c = inp                      # (B,cl,...)
        dA = dt_c * A                                  # (B,cl,H) fp32
        cum = jnp.cumsum(dA, axis=1)
        gap = cum[:, :, None, :] - cum[:, None, :, :]  # (B,cl,cl,H)
        Lm = jnp.exp(jnp.where(tri[None, :, :, None], gap, -jnp.inf))
        xdt = x_c.astype(f32) * dt_c[..., None]
        y_intra = jnp.einsum("bin,bjn,bijh,bjhp->bihp", c_c.astype(f32),
                             b_c.astype(f32), Lm, xdt)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", c_c.astype(f32), state,
                             jnp.exp(cum))
        decay_end = jnp.exp(cum[:, -1:, :] - cum)      # (B,cl,H)
        st_new = jnp.einsum("bjn,bjh,bjhp->bhpn", b_c.astype(f32),
                            decay_end * dt_c, x_c.astype(f32))
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + st_new
        return state, y_intra + y_inter

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    final, ys = jax.lax.scan(body, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)
    return y.astype(xh.dtype), final


def mamba_block(cfg: ModelConfig, p, x, *, cache=None):
    """x: (B,T,D).  cache: dict(conv (B,k-1,d_conv), state (B,H,P,N)) for
    T==1 decode; None for train/prefill (prefill returns a fresh cache).
    Returns (out, new_cache)."""
    s = cfg.ssm
    d_in, nh, d_conv = _dims(cfg)
    B, T, D = x.shape
    cd = cfg.cdtype
    P = s.headdim

    zxbcdt = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(cd))
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    z = shard(z, "batch", "seq", "ssm_heads")
    xbc = shard(xbc, "batch", "seq", None)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt_f = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"].astype(jnp.float32))

    if cache is not None and T == 1:  # single-step decode
        conv_buf = cache["conv"]                       # (B, k-1, d_conv)
        full = jnp.concatenate([conv_buf.astype(cd), xbc], axis=1)
        w = p["conv_w"].astype(cd)
        conv_out = p["conv_b"].astype(cd) + sum(
            full[:, i, :] * w[i] for i in range(s.conv))
        xbc_a = jax.nn.silu(conv_out)[:, None, :]      # (B,1,d_conv)
        new_conv = full[:, 1:, :].astype(conv_buf.dtype)
        xh = xbc_a[..., :d_in].reshape(B, nh, P)
        Bm = xbc_a[..., d_in: d_in + s.state][:, 0]
        Cm = xbc_a[..., d_in + s.state:][:, 0]
        state = cache["state"].astype(jnp.float32)
        dA = jnp.exp(dt_f[:, 0] * A)                   # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bm.astype(jnp.float32),
                         dt_f[:, 0], xh.astype(jnp.float32))
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
        y = y + p["D_skip"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
        y = y.reshape(B, 1, d_in).astype(cd)
        new_cache = {"conv": new_conv, "state": state.astype(cache["state"].dtype)}
    else:
        xbc_a = jax.nn.silu(_conv_causal(xbc, p["conv_w"].astype(cd),
                                         p["conv_b"].astype(cd)))
        xh = xbc_a[..., :d_in].reshape(B, T, nh, P)
        xh = shard(xh, "batch", "seq", "ssm_heads", None)
        Bm = xbc_a[..., d_in: d_in + s.state]
        Cm = xbc_a[..., d_in + s.state:]
        y, final = _ssd_scan(cfg, xh, dt_f, A, Bm, Cm)
        y = y + (p["D_skip"].astype(jnp.float32)[:, None]
                 * xh.astype(jnp.float32)).astype(y.dtype)
        y = y.reshape(B, T, d_in)
        new_cache = None
        if cache is not None:  # prefill: emit decode-ready cache
            tail = xbc[:, -(s.conv - 1):, :] if T >= s.conv - 1 else jnp.pad(
                xbc, ((0, 0), (s.conv - 1 - T, 0), (0, 0)))
            new_cache = {"conv": tail.astype(cache["conv"].dtype),
                         "state": final.astype(cache["state"].dtype)}

    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(cd),
                p["norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(cd))
    return shard(out, "batch", "seq", "d_model"), new_cache
