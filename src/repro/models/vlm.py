"""LLaVA-NeXT-style VLM: Mistral-7B backbone + stubbed vision frontend.

Per assignment, the vision tower + anyres tiling are a STUB:
`input_specs()` provides precomputed patch embeddings already projected to
d_model (B, n_img_tokens, D).  They are spliced in front of the text
embeddings (early fusion); loss is computed on text positions only; the KV
cache covers image + text positions so decode is standard."""
from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as ly
from . import transformer as tf

init_params = tf.init_params
init_cache = tf.init_cache
cache_specs = tf.cache_specs
decode_step = tf.decode_step


def _fuse(cfg: ModelConfig, params, img_embeds, tokens):
    tok = ly.embed_tokens(cfg, params, tokens)
    x = jnp.concatenate([img_embeds.astype(cfg.cdtype), tok], axis=1)
    return ly.shard(x, "batch", "seq", "d_model")


def loss_fn(cfg: ModelConfig, params, batch):
    img, tokens, labels = batch["img_embeds"], batch["tokens"], batch["labels"]
    x = _fuse(cfg, params, img, tokens)
    positions = jnp.arange(x.shape[1])
    x, _, aux = tf.backbone(cfg, params, x, positions)
    # text positions only
    x_text = x[:, img.shape[1]:, :]
    logits = ly.logits_from_hidden(cfg, params, x_text)
    return ly.cross_entropy(logits, labels) + aux


def prefill(cfg: ModelConfig, params, batch, cache):
    x = _fuse(cfg, params, batch["img_embeds"], batch["tokens"])
    positions = jnp.arange(x.shape[1])
    x, new_caches, _ = tf.backbone(cfg, params, x, positions, caches=cache,
                                   cache_pos=0)
    logits = ly.logits_from_hidden(cfg, params, x[:, -1:, :])
    return logits[:, 0], new_caches
