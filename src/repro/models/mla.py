"""Multi-head Latent Attention (DeepSeek-V2), in the weight-absorbed form.

The KV cache stores only the compressed latent c_kv (kv_lora) plus the
shared rope key (rope_dim) per position — the memory win that defines
MLA.  Queries are absorbed into the latent space (q_lat = q_nope @ W_uk)
so attention scores are computed directly against the cached latents and
the output is decompressed once per query (production decode path; the
naive decompress-all-keys form is never materialized).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import shard
from .layers import ParamBuilder, rmsnorm, rope, softmax_fp32


def init_mla(b: ParamBuilder, cfg: ModelConfig, L: int, prefix: str = "attn"):
    m, a = cfg.mla, cfg.attn
    D, H = cfg.d_model, a.n_heads
    s = b.sub(prefix)
    s.make("wq", (L, D, H * (m.nope_dim + m.rope_dim)),
           ("layers", "d_model", "heads"))
    s.make("w_dkv", (L, D, m.kv_lora), ("layers", "d_model", "kv_lora"))
    s.make("w_krope", (L, D, m.rope_dim), ("layers", "d_model", "head_dim"))
    s.make("kv_norm", (L, m.kv_lora), ("layers", "kv_lora"), init="ones")
    s.make("w_uk", (L, m.kv_lora, H * m.nope_dim),
           ("layers", "kv_lora", "heads"))
    s.make("w_uv", (L, m.kv_lora, H * m.v_dim),
           ("layers", "kv_lora", "heads"))
    s.make("wo", (L, H * m.v_dim, D), ("layers", "heads", "d_model"))


def mla_attention(cfg: ModelConfig, p, x, positions, *, cache=None,
                  cache_pos=None, causal=True):
    m, a = cfg.mla, cfg.attn
    H = a.n_heads
    B, T, D = x.shape
    cd = cfg.cdtype
    scale = 1.0 / math.sqrt(m.nope_dim + m.rope_dim)

    q = jnp.einsum("btd,dh->bth", x, p["wq"].astype(cd))
    q = shard(q, "batch", "seq", "heads").reshape(B, T, H, m.nope_dim + m.rope_dim)
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim:]
    q_rope = rope(q_rope, positions, a.rope_theta)

    ckv = rmsnorm(jnp.einsum("btd,dl->btl", x, p["w_dkv"].astype(cd)),
                  p["kv_norm"], cfg.norm_eps)
    kr = jnp.einsum("btd,dr->btr", x, p["w_krope"].astype(cd))
    kr = rope(kr[:, :, None, :], positions, a.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_pos, axis=1)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["kr"], kr.astype(cache["kr"].dtype), cache_pos, axis=1)
        new_cache = {"ckv": cc, "kr": ck}
        ckv, kr = cc.astype(cd), ck.astype(cd)
    S = ckv.shape[1]

    # absorb W_uk into the query -> latent-space scores
    w_uk = p["w_uk"].astype(cd).reshape(m.kv_lora, H, m.nope_dim)
    q_lat = jnp.einsum("bthn,lhn->bthl", q_nope, w_uk)
    q_lat = shard(q_lat, "batch", "seq", "heads", None)
    q_pos = positions if positions.ndim else positions[None]
    kv_pos = jnp.arange(S)

    def attend(ql, qr, qp):
        scores = (jnp.einsum("bthl,bsl->bhts", ql, ckv,
                             preferred_element_type=cd)
                  + jnp.einsum("bthr,bsr->bhts", qr, kr,
                               preferred_element_type=cd)) * scale
        if causal:
            mask = (qp[:, None] >= kv_pos[None, :])[None, None]
            w = softmax_fp32(scores, mask).astype(cd)
        else:
            w = softmax_fp32(scores).astype(cd)
        return jnp.einsum("bhts,bsl->bthl", w, ckv,
                          preferred_element_type=cd)

    qc_len = cfg.q_chunk
    if T > qc_len and T % qc_len == 0 and q_pos.ndim == 1:
        nc = T // qc_len
        qls = jnp.moveaxis(q_lat.reshape(B, nc, qc_len, H, m.kv_lora), 1, 0)
        qrs = jnp.moveaxis(q_rope.reshape(B, nc, qc_len, H, m.rope_dim), 1, 0)
        ps = q_pos.reshape(nc, qc_len)
        _, lats = jax.lax.scan(
            lambda _, xs: (None, attend(*xs)), None, (qls, qrs, ps))
        lat = jnp.moveaxis(lats, 0, 1).reshape(B, T, H, m.kv_lora)
    else:
        lat = attend(q_lat, q_rope, q_pos)

    # decompress once per query
    w_uv = p["w_uv"].astype(cd).reshape(m.kv_lora, H, m.v_dim)
    out = jnp.einsum("bthl,lhv->bthv", lat, w_uv,
                     preferred_element_type=cd).reshape(B, T, H * m.v_dim)
    out = shard(out, "batch", "seq", "heads")
    out = jnp.einsum("bth,hd->btd", out, p["wo"].astype(cd))
    return shard(out, "batch", "seq", "d_model"), new_cache
