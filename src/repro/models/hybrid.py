"""Zamba-2-style hybrid: Mamba-2 backbone + a *shared* attention block
(one set of weights, applied every `shared_attn_period` layers, each
application with its own KV cache — weights shared, state not).

Deviation note (DESIGN.md §8): real Zamba-2 concatenates the original
embedding into the shared block input and adds per-application LoRA
deltas; we apply a standard pre-norm shared block (same weights each
time), which preserves the defining weight-sharing/memory character.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as ly
from .ssm import init_mamba, mamba_block


def n_shared_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_period


def init_params(cfg: ModelConfig, rng):
    b = ly.ParamBuilder(rng, cfg.pdtype)
    ly.init_embed(b, cfg)
    mb = b.sub("mamba")
    mb.make("ln", (cfg.n_layers, cfg.d_model), ("layers", "d_model"),
            init="ones")
    init_mamba(mb, cfg, cfg.n_layers)
    sb = b.sub("shared")
    sb.make("ln_attn", (1, cfg.d_model), ("layers", "d_model"), init="ones")
    sb.make("ln_mlp", (1, cfg.d_model), ("layers", "d_model"), init="ones")
    ly.init_attention(sb, cfg, 1)
    ly.init_mlp(sb, cfg, 1)
    return b.params, b.specs


def _shared_block(cfg, sp, x, positions, cache, cache_pos):
    p = jax.tree.map(lambda a: a[0], sp)       # drop the L=1 stack axis
    h = ly.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
    att, new_cache = ly.attention(cfg, p["attn"], h, positions, cache=cache,
                                  cache_pos=cache_pos)
    x = x + att
    h = ly.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    return x + ly.mlp(cfg, p["mlp"], h), new_cache


def backbone(cfg: ModelConfig, params, x, positions, caches=None,
             cache_pos=None):
    """caches: {"ssm": stacked (L,...) conv/state, "attn": stacked
    (n_apps,...) k/v} or None."""
    period = cfg.shared_attn_period
    apps = n_shared_apps(cfg)
    policy = ly.remat_policy(cfg.remat)
    mp = params["mamba"]
    new_ssm, new_attn = ([] if caches is not None else None,
                         [] if caches is not None else None)

    def mamba_step(h, xs):
        layer_p, layer_c = xs
        hn = ly.rmsnorm(h, layer_p["ln"], cfg.norm_eps)
        out, nc = mamba_block(cfg, layer_p["ssm"], hn, cache=layer_c)
        return h + out, (nc if nc is not None else {})

    step_fn = (jax.checkpoint(mamba_step, policy=policy, prevent_cse=False)
               if policy is not None and caches is None else mamba_step)

    for a in range(apps):
        lo = a * period
        seg_p = jax.tree.map(lambda t: t[lo: lo + period], mp)
        seg_c = (jax.tree.map(lambda t: t[lo: lo + period], caches["ssm"])
                 if caches is not None else None)
        x, seg_new = jax.lax.scan(step_fn, x, (seg_p, seg_c))
        ac = (jax.tree.map(lambda t: t[a], caches["attn"])
              if caches is not None else None)
        x, nc = _shared_block(cfg, params["shared"], x, positions, ac,
                              cache_pos)
        if caches is not None:
            new_ssm.append(seg_new)
            new_attn.append(nc)
    # trailing mamba layers (n_layers not divisible by period)
    lo = apps * period
    if lo < cfg.n_layers:
        seg_p = jax.tree.map(lambda t: t[lo:], mp)
        seg_c = (jax.tree.map(lambda t: t[lo:], caches["ssm"])
                 if caches is not None else None)
        x, seg_new = jax.lax.scan(step_fn, x, (seg_p, seg_c))
        if caches is not None:
            new_ssm.append(seg_new)
    new_caches = None
    if caches is not None:
        new_caches = {
            "ssm": jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_ssm),
            "attn": jax.tree.map(lambda *xs: jnp.stack(xs), *new_attn),
        }
    return x, new_caches, jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    from .ssm import _dims
    dtype = dtype or cfg.cdtype
    s = cfg.ssm
    d_in, nh, d_conv = _dims(cfg)
    a = cfg.attn
    apps = n_shared_apps(cfg)
    return {
        "ssm": {
            "conv": jnp.zeros((cfg.n_layers, batch, s.conv - 1, d_conv), dtype),
            "state": jnp.zeros((cfg.n_layers, batch, nh, s.headdim, s.state),
                               jnp.float32),
        },
        "attn": {
            "k": jnp.zeros((apps, batch, seq_len, a.n_kv, a.head_dim), dtype),
            "v": jnp.zeros((apps, batch, seq_len, a.n_kv, a.head_dim), dtype),
        },
    }


def cache_specs(cfg: ModelConfig):
    return {
        "ssm": {"conv": ("layers", "batch", "conv", "ssm_heads"),
                "state": ("layers", "batch", "ssm_heads", None, "ssm_state")},
        "attn": {"k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                 "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim")},
    }


def loss_fn(cfg: ModelConfig, params, batch):
    tokens, labels = batch["tokens"], batch["labels"]
    x = ly.embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    x, _, aux = backbone(cfg, params, x, positions)
    logits = ly.logits_from_hidden(cfg, params, x)
    return ly.cross_entropy(logits, labels) + aux


def prefill(cfg: ModelConfig, params, tokens, cache):
    x = ly.embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    x, new_caches, _ = backbone(cfg, params, x, positions, caches=cache,
                                cache_pos=0)
    logits = ly.logits_from_hidden(cfg, params, x[:, -1:, :])
    return logits[:, 0], new_caches


def decode_step(cfg: ModelConfig, params, tokens, cache, pos):
    x = ly.embed_tokens(cfg, params, tokens[:, None])
    positions = pos[None] if hasattr(pos, "ndim") else jnp.asarray([pos])
    x, new_caches, _ = backbone(cfg, params, x, positions, caches=cache,
                                cache_pos=pos)
    logits = ly.logits_from_hidden(cfg, params, x)
    return logits[:, 0], new_caches
