"""Model facade: uniform init/loss/prefill/decode over all families, plus
abstract (no-allocation) init and ShapeDtypeStruct input specs for the
multi-pod dry-run."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import transformer, hybrid, encdec, vlm


def _family_mod(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return transformer
    if cfg.family == "ssm":
        return transformer_ssm
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "audio":
        return encdec
    if cfg.family == "vlm":
        return vlm
    raise ValueError(cfg.family)


# ---- pure-SSM decoder LM (mamba2): reuse hybrid with period 0 --------------
class _SSMModule:
    """Mamba-2 decoder LM = hybrid backbone with no shared attention."""

    @staticmethod
    def init_params(cfg, rng):
        from . import layers as ly
        from .ssm import init_mamba
        b = ly.ParamBuilder(rng, cfg.pdtype)
        ly.init_embed(b, cfg)
        mb = b.sub("mamba")
        mb.make("ln", (cfg.n_layers, cfg.d_model), ("layers", "d_model"),
                init="ones")
        init_mamba(mb, cfg, cfg.n_layers)
        return b.params, b.specs

    @staticmethod
    def _backbone(cfg, params, x, caches=None):
        from . import layers as ly
        from .ssm import mamba_block
        policy = ly.remat_policy(cfg.remat)

        def step(h, xs):
            layer_p, layer_c = xs
            hn = ly.rmsnorm(h, layer_p["ln"], cfg.norm_eps)
            out, nc = mamba_block(cfg, layer_p["ssm"], hn, cache=layer_c)
            return h + out, (nc if nc is not None else {})

        step_fn = (jax.checkpoint(step, policy=policy, prevent_cse=False)
                   if policy is not None and caches is None else step)
        x, new_c = jax.lax.scan(step_fn, x, (params["mamba"], caches))
        return x, (new_c if caches is not None else None)

    @staticmethod
    def loss_fn(cfg, params, batch):
        from . import layers as ly
        x = ly.embed_tokens(cfg, params, batch["tokens"])
        x, _ = _SSMModule._backbone(cfg, params, x)
        logits = ly.logits_from_hidden(cfg, params, x)
        return ly.cross_entropy(logits, batch["labels"])

    @staticmethod
    def init_cache(cfg, batch, seq_len, dtype=None):
        from .ssm import _dims
        dtype = dtype or cfg.cdtype
        s = cfg.ssm
        d_in, nh, d_conv = _dims(cfg)
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, s.conv - 1, d_conv), dtype),
            "state": jnp.zeros((cfg.n_layers, batch, nh, s.headdim, s.state),
                               jnp.float32),
        }

    @staticmethod
    def cache_specs(cfg):
        return {"conv": ("layers", "batch", "conv", "ssm_heads"),
                "state": ("layers", "batch", "ssm_heads", None, "ssm_state")}

    @staticmethod
    def prefill(cfg, params, tokens, cache):
        from . import layers as ly
        x = ly.embed_tokens(cfg, params, tokens)
        x, new_c = _SSMModule._backbone(cfg, params, x, caches=cache)
        logits = ly.logits_from_hidden(cfg, params, x[:, -1:, :])
        return logits[:, 0], new_c

    @staticmethod
    def decode_step(cfg, params, tokens, cache, pos):
        from . import layers as ly
        x = ly.embed_tokens(cfg, params, tokens[:, None])
        x, new_c = _SSMModule._backbone(cfg, params, x, caches=cache)
        logits = ly.logits_from_hidden(cfg, params, x)
        return logits[:, 0], new_c


transformer_ssm = _SSMModule


@dataclass
class Model:
    cfg: ModelConfig

    # ---- params ------------------------------------------------------------
    def init(self, rng):
        return _family_mod(self.cfg).init_params(self.cfg, rng)

    def abstract_init(self):
        """(ShapeDtypeStruct params tree, logical-axis spec tree) without
        allocating anything — used by the dry-run."""
        side: dict[str, Any] = {}

        def f(key):
            p, s = _family_mod(self.cfg).init_params(self.cfg, key)
            side["specs"] = s
            return p

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, side["specs"]

    # ---- steps ---------------------------------------------------------------
    def loss(self, params, batch):
        return _family_mod(self.cfg).loss_fn(self.cfg, params, batch)

    def prefill(self, params, batch, cache):
        mod = _family_mod(self.cfg)
        if self.cfg.family in ("audio", "vlm"):
            return mod.prefill(self.cfg, params, batch, cache)
        return mod.prefill(self.cfg, params, batch["tokens"], cache)

    def decode(self, params, tokens, cache, pos):
        return _family_mod(self.cfg).decode_step(self.cfg, params, tokens,
                                                 cache, pos)

    # ---- caches ---------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int):
        return _family_mod(self.cfg).init_cache(self.cfg, batch, seq_len)

    def cache_specs(self):
        return _family_mod(self.cfg).cache_specs(self.cfg)

    def abstract_cache(self, batch: int, seq_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, seq_len))

    # ---- dry-run input specs ---------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of the step
        selected by shape.kind (tokens/labels/frames/img_embeds/cache)."""
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            batch = {"tokens": sds((B, T), i32), "labels": sds((B, T), i32)}
            if cfg.family == "audio":
                batch["frames"] = sds((B, cfg.enc_frames, cfg.d_model),
                                      cfg.cdtype)
            if cfg.family == "vlm":
                n_txt = T - cfg.n_img_tokens
                batch = {"tokens": sds((B, n_txt), i32),
                         "labels": sds((B, n_txt), i32),
                         "img_embeds": sds((B, cfg.n_img_tokens, cfg.d_model),
                                           cfg.cdtype)}
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = {"tokens": sds((B, T), i32)}
            if cfg.family == "audio":
                batch["frames"] = sds((B, cfg.enc_frames, cfg.d_model),
                                      cfg.cdtype)
            if cfg.family == "vlm":
                batch = {"tokens": sds((B, T - cfg.n_img_tokens), i32),
                         "img_embeds": sds((B, cfg.n_img_tokens, cfg.d_model),
                                           cfg.cdtype)}
            cache = self.abstract_cache(B, T)
            return {"batch": batch, "cache": cache}
        if shape.kind == "decode":
            cache = self.abstract_cache(B, T)
            return {"tokens": sds((B,), i32), "cache": cache,
                    "pos": sds((), i32)}
        raise ValueError(shape.kind)
