"""Mixture-of-Experts block: GShard-style grouped one-hot dispatch.

Tokens are viewed as (G groups, Sg tokens) with groups following the batch
sharding; experts are sharded over the `model` mesh axis (EP).  The
dispatch/combine einsums reshard tokens from batch-sharded to
expert-sharded layout — XLA SPMD inserts the all-to-alls (visible in the
dry-run collective table; the §Perf loop tunes group_size/capacity and,
beyond the baseline, swaps in a sort-based dispatch).

Capacity dropping: tokens routed past an expert's capacity fall through
via the residual connection (combine weights are zero), standard
Switch/GShard semantics.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from ..runtime.sharding import shard
from .layers import ParamBuilder


def init_moe(b: ParamBuilder, cfg: ModelConfig, L: int, prefix: str = "moe"):
    mo = cfg.moe
    D, F, E = cfg.d_model, mo.expert_ff, mo.n_experts
    s = b.sub(prefix)
    s.make("router", (L, D, E), ("layers", "d_model", "experts"),
           scale=1.0 / math.sqrt(D))
    s.make("wi_g", (L, E, D, F), ("layers", "experts", "d_model", "expert_ffn"),
           scale=1.0 / math.sqrt(D))
    s.make("wi", (L, E, D, F), ("layers", "experts", "d_model", "expert_ffn"),
           scale=1.0 / math.sqrt(D))
    s.make("wo", (L, E, F, D), ("layers", "experts", "expert_ffn", "d_model"),
           scale=1.0 / math.sqrt(F))
    if mo.n_shared:
        Fs = mo.n_shared * F
        s.make("sh_wi_g", (L, D, Fs), ("layers", "d_model", "ffn"))
        s.make("sh_wi", (L, D, Fs), ("layers", "d_model", "ffn"))
        s.make("sh_wo", (L, Fs, D), ("layers", "ffn", "d_model"))


def _capacity(sg: int, mo: MoEConfig) -> int:
    c = int(math.ceil(sg * mo.top_k * mo.capacity_factor / mo.n_experts))
    return max(1, -(-c // 4) * 4) if c > 4 else max(1, c)


def moe_block(cfg: ModelConfig, p, x):
    """x: (B, T, D) -> (out, aux_loss)."""
    mo = cfg.moe
    E, k = mo.n_experts, mo.top_k
    B, T, D = x.shape
    cd = cfg.cdtype

    # group view: rows of at most group_size tokens
    sg = min(mo.group_size, T)
    n_split = T // sg if T % sg == 0 else 1
    if T % sg != 0:
        sg = T
    G = B * n_split
    xg = x.reshape(G, sg, D)
    xg = shard(xg, "groups", None, "d_model")

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,Sg,E)
    topv, topi = jax.lax.top_k(probs, k)                       # (G,Sg,k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    C = _capacity(sg, mo)
    counts = jnp.zeros((G, E), jnp.int32)
    dispatch = jnp.zeros((G, sg, E, C), cd)
    combine = jnp.zeros((G, sg, E, C), jnp.float32)
    for j in range(k):  # GShard: allocate capacity choice-by-choice
        oh = jax.nn.one_hot(topi[..., j], E, dtype=jnp.int32)  # (G,Sg,E)
        pos = jnp.cumsum(oh, axis=1) - 1 + counts[:, None, :]  # slot per token
        counts = counts + oh.sum(axis=1)
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                                dtype=jnp.float32)[..., :C]    # (G,Sg,E,C)
        sel = pos_oh * oh[..., None].astype(jnp.float32)
        dispatch = dispatch + sel.astype(cd)
        combine = combine + sel * topv[..., j][..., None, None]

    dispatch = shard(dispatch, "groups", None, "experts", None)
    combine = shard(combine, "groups", None, "experts", None)

    # tokens -> expert buffers (all-to-all under EP sharding)
    xin = jnp.einsum("gsec,gsd->gecd", dispatch, xg.astype(cd),
                     preferred_element_type=cd)
    xin = shard(xin, "groups", "experts", None, "d_model")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wi_g"].astype(cd),
                               preferred_element_type=cd)) \
        * jnp.einsum("gecd,edf->gecf", xin, p["wi"].astype(cd),
                     preferred_element_type=cd)
    h = shard(h, "groups", "experts", None, "expert_ffn")
    eout = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(cd),
                      preferred_element_type=cd)
    eout = shard(eout, "groups", "experts", None, "d_model")
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(cd), eout,
                     preferred_element_type=cd)
    out = out.reshape(B, T, D)

    if mo.n_shared:
        g = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["sh_wi_g"].astype(cd)))
        hs = g * jnp.einsum("btd,df->btf", x, p["sh_wi"].astype(cd))
        out = out + jnp.einsum("btf,fd->btd", hs, p["sh_wo"].astype(cd))

    # load-balance aux (Switch): E * sum_e f_e * p_e
    frac = jnp.mean((jax.nn.one_hot(topi[..., 0], E)), axis=(0, 1))
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p) * mo.aux_weight
    return shard(out, "batch", "seq", "d_model"), aux
