"""Whisper-style encoder-decoder backbone.

Frontend stub (per assignment): `input_specs()` provides precomputed frame
embeddings (B, enc_frames, D) — i.e. the output of Whisper's conv stem —
so the encoder here is sinusoid + transformer layers.  The decoder is a
standard causal stack with cross-attention over cached encoder memory
(projected K/V cached at prefill, Whisper's production serving layout).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as ly


def _sinusoid(length: int, dim: int):
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / dim)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=-1),
                       jnp.float32)


def init_params(cfg: ModelConfig, rng):
    b = ly.ParamBuilder(rng, cfg.pdtype)
    ly.init_embed(b, cfg)
    b.make("dec_pos", (32_768, cfg.d_model), (None, "d_model"), init="embed")
    enc = b.sub("enc")
    enc.make("ln_attn", (cfg.enc_layers, cfg.d_model), ("layers", "d_model"),
             init="ones")
    enc.make("ln_mlp", (cfg.enc_layers, cfg.d_model), ("layers", "d_model"),
             init="ones")
    ly.init_attention(enc, cfg, cfg.enc_layers)
    ly.init_mlp(enc, cfg, cfg.enc_layers, gated=False)
    enc.make("final_norm", (cfg.d_model,), ("d_model",), init="ones")
    dec = b.sub("dec")
    dec.make("ln_self", (cfg.n_layers, cfg.d_model), ("layers", "d_model"),
             init="ones")
    dec.make("ln_x", (cfg.n_layers, cfg.d_model), ("layers", "d_model"),
             init="ones")
    dec.make("ln_mlp", (cfg.n_layers, cfg.d_model), ("layers", "d_model"),
             init="ones")
    ly.init_attention(dec, cfg, cfg.n_layers, prefix="self_attn")
    ly.init_cross_attention(dec, cfg, cfg.n_layers)
    ly.init_mlp(dec, cfg, cfg.n_layers, gated=False)
    return b.params, b.specs


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, S_enc, D) stubbed conv-stem output."""
    x = frames.astype(cfg.cdtype) + _sinusoid(frames.shape[1],
                                              cfg.d_model).astype(cfg.cdtype)
    positions = jnp.arange(frames.shape[1])
    ep = params["enc"]

    def step(h, layer_p):
        hn = ly.rmsnorm(h, layer_p["ln_attn"], cfg.norm_eps)
        att, _ = ly.attention(cfg, layer_p["attn"], hn, positions,
                              causal=False)
        h = h + att
        hn = ly.rmsnorm(h, layer_p["ln_mlp"], cfg.norm_eps)
        return h + ly.mlp(cfg, layer_p["mlp"], hn, gated=False), None

    stack = {k: ep[k] for k in ("ln_attn", "ln_mlp", "attn", "mlp")}
    x, _ = jax.lax.scan(lambda h, p: step(h, p), x, stack)
    return ly.rmsnorm(x, ep["final_norm"], cfg.norm_eps)


def project_memory_all(cfg: ModelConfig, params, enc_out):
    """Per-decoder-layer cross-attn K/V: (L, B, S_enc, K, Dh) pair."""
    dp = params["dec"]["xattn"]

    def proj(layer_p):
        return ly.project_memory(cfg, layer_p, enc_out)

    mk, mv = jax.vmap(proj)(dp)
    return mk, mv


def _decoder(cfg: ModelConfig, params, x, positions, mem_k, mem_v,
             cache=None, cache_pos=None):
    dp = params["dec"]
    policy = ly.remat_policy(cfg.remat)

    def step(h, xs):
        layer_p, mk, mv, layer_c = xs
        hn = ly.rmsnorm(h, layer_p["ln_self"], cfg.norm_eps)
        att, nc = ly.attention(cfg, layer_p["self_attn"], hn, positions,
                               cache=layer_c, cache_pos=cache_pos)
        h = h + att
        hn = ly.rmsnorm(h, layer_p["ln_x"], cfg.norm_eps)
        h = h + ly.cross_attention(cfg, layer_p["xattn"], hn, mk, mv)
        hn = ly.rmsnorm(h, layer_p["ln_mlp"], cfg.norm_eps)
        return h + ly.mlp(cfg, layer_p["mlp"], hn, gated=False), \
            (nc if nc is not None else {})

    step_fn = (jax.checkpoint(step, policy=policy, prevent_cse=False)
               if policy is not None and cache is None else step)
    stack = {k: dp[k] for k in ("ln_self", "ln_x", "ln_mlp", "self_attn",
                                "xattn", "mlp")}
    x, new_c = jax.lax.scan(step_fn, x, (stack, mem_k, mem_v, cache))
    return x, new_c


def _embed_dec(cfg, params, tokens, pos0):
    x = ly.embed_tokens(cfg, params, tokens)
    T = tokens.shape[1]
    pe = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos0, T, axis=0)
    return x + pe.astype(cfg.cdtype)


def loss_fn(cfg: ModelConfig, params, batch):
    frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
    enc = encode(cfg, params, frames)
    mk, mv = project_memory_all(cfg, params, enc)
    x = _embed_dec(cfg, params, tokens, 0)
    positions = jnp.arange(tokens.shape[1])
    x, _ = _decoder(cfg, params, x, positions, mk, mv)
    logits = ly.logits_from_hidden(cfg, params, x)
    return ly.cross_entropy(logits, labels)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.cdtype
    a = cfg.attn
    L = cfg.n_layers
    return {
        "self": {"k": jnp.zeros((L, batch, seq_len, a.n_kv, a.head_dim), dtype),
                 "v": jnp.zeros((L, batch, seq_len, a.n_kv, a.head_dim), dtype)},
        "mem": {"k": jnp.zeros((L, batch, cfg.enc_frames, a.n_kv, a.head_dim),
                               dtype),
                "v": jnp.zeros((L, batch, cfg.enc_frames, a.n_kv, a.head_dim),
                               dtype)},
    }


def cache_specs(cfg: ModelConfig):
    kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    mem = ("layers", "batch", "frames", "kv_heads", "head_dim")
    return {"self": {"k": kv, "v": kv}, "mem": {"k": mem, "v": mem}}


def prefill(cfg: ModelConfig, params, batch, cache):
    """batch: dict(frames, tokens).  Encodes audio + prompt tokens."""
    enc = encode(cfg, params, batch["frames"])
    mk, mv = project_memory_all(cfg, params, enc)
    tokens = batch["tokens"]
    x = _embed_dec(cfg, params, tokens, 0)
    positions = jnp.arange(tokens.shape[1])
    x, new_self = _decoder(cfg, params, x, positions, mk, mv,
                           cache=cache["self"], cache_pos=0)
    logits = ly.logits_from_hidden(cfg, params, x[:, -1:, :])
    new_cache = {"self": new_self,
                 "mem": {"k": mk.astype(cache["mem"]["k"].dtype),
                         "v": mv.astype(cache["mem"]["v"].dtype)}}
    return logits[:, 0], new_cache


def decode_step(cfg: ModelConfig, params, tokens, cache, pos):
    x = _embed_dec(cfg, params, tokens[:, None], pos)
    positions = pos[None] if hasattr(pos, "ndim") else jnp.asarray([pos])
    mk = cache["mem"]["k"].astype(cfg.cdtype)
    mv = cache["mem"]["v"].astype(cfg.cdtype)
    x, new_self = _decoder(cfg, params, x, positions, mk, mv,
                           cache=cache["self"], cache_pos=pos)
    logits = ly.logits_from_hidden(cfg, params, x)
    return logits[:, 0], {"self": new_self, "mem": cache["mem"]}
