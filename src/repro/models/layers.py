"""Common model layers (pure JAX, no flax).

Params are nested dicts built through `ParamBuilder`, which records a
parallel tree of logical-axis tuples consumed by runtime/sharding for
NamedSharding placement (and by the dry-run for in_shardings).

Conventions:
  * params stored in cfg.param_dtype, compute in cfg.compute_dtype,
    softmax/logits/loss in fp32;
  * attention uses grouped-query form (B, T, K, G, Dh);
  * KV caches are (B, S, K, Dh) per layer, stacked (L, ...) for scan;
  * activations are annotated with logical axes via sharding.shard().
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import AttnConfig, ModelConfig
from ..runtime.sharding import shard


class ParamBuilder:
    """Builds (params, specs) trees in lockstep so they can't drift."""

    def __init__(self, key: jax.Array, dtype):
        self.key = key
        self.dtype = dtype
        self.params: dict[str, Any] = {}
        self.specs: dict[str, Any] = {}

    def _next(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def make(self, name: str, shape, axes, init: str = "fan_in",
             scale: float | None = None):
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            p = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            p = jnp.ones(shape, self.dtype)
        else:
            if scale is None:
                if init == "embed":
                    scale = 0.02
                else:  # fan_in over all but the last axis
                    fan_in = math.prod(shape[:-1]) if len(shape) > 1 else shape[0]
                    scale = 1.0 / math.sqrt(max(1, fan_in))
            p = (jax.random.normal(self._next(), shape, jnp.float32)
                 * scale).astype(self.dtype)
        self.params[name] = p
        self.specs[name] = tuple(axes)
        return p

    def sub(self, name: str) -> "ParamBuilder":
        child = ParamBuilder.__new__(ParamBuilder)
        child.key = self._next()
        child.dtype = self.dtype
        child.params = self.params.setdefault(name, {})
        child.specs = self.specs.setdefault(name, {})
        return child


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: (..., T, n, Dh); positions: (T,) or (B, T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    if cos.ndim < x.ndim:  # broadcast batch dims
        cos = jnp.expand_dims(cos, 0)
        sin = jnp.expand_dims(sin, 0)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softmax_fp32(scores, mask=None):
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    return jax.nn.softmax(scores, axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / qkv-bias, KV cache)
# ---------------------------------------------------------------------------

def init_attention(b: ParamBuilder, cfg: ModelConfig, L: int,
                   prefix: str = "attn"):
    a = cfg.attn
    D, H, K, Dh = cfg.d_model, a.n_heads, a.n_kv, a.head_dim
    s = b.sub(prefix)
    s.make("wq", (L, D, H * Dh), ("layers", "d_model", "heads"))
    s.make("wk", (L, D, K * Dh), ("layers", "d_model", "kv_heads"))
    s.make("wv", (L, D, K * Dh), ("layers", "d_model", "kv_heads"))
    s.make("wo", (L, H * Dh, D), ("layers", "heads", "d_model"))
    if a.qkv_bias:
        s.make("bq", (L, H * Dh), ("layers", "heads"), init="zeros")
        s.make("bk", (L, K * Dh), ("layers", "kv_heads"), init="zeros")
        s.make("bv", (L, K * Dh), ("layers", "kv_heads"), init="zeros")
    if a.qk_norm:
        s.make("q_norm", (L, Dh), ("layers", "head_dim"), init="ones")
        s.make("k_norm", (L, Dh), ("layers", "head_dim"), init="ones")


def attention(cfg: ModelConfig, p, x, positions, *, cache=None,
              cache_pos=None, causal=True, a: AttnConfig | None = None):
    """p: this layer's attn params (no leading L).  cache: dict(k, v) of
    (B, S, K, Dh) or None.  cache_pos: scalar write offset into the cache.
    Returns (out, new_cache)."""
    a = a or cfg.attn
    H, K, Dh = a.n_heads, a.n_kv, a.head_dim
    G = H // K
    B, T, D = x.shape
    cd = cfg.cdtype

    q = jnp.einsum("btd,dh->bth", x, p["wq"].astype(cd))
    k = jnp.einsum("btd,dh->bth", x, p["wk"].astype(cd))
    v = jnp.einsum("btd,dh->bth", x, p["wv"].astype(cd))
    if a.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    from ..runtime.sharding import heads_divisible
    q = shard(q, "batch", "seq", "heads" if heads_divisible("heads", H)
              else None)
    kv_ax = "kv_heads" if heads_divisible("kv_heads", K) else None
    k = shard(k, "batch", "seq", kv_ax)
    v = shard(v, "batch", "seq", kv_ax)
    q = q.reshape(B, T, H, Dh)
    k = k.reshape(B, T, K, Dh)
    v = v.reshape(B, T, K, Dh)
    if a.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, a.rope_theta)
    k = rope(k, positions, a.rope_theta)

    new_cache = None
    if cache is not None:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(cd), cv.astype(cd)
    # split-KV: under decode/prefill rules kv_seq maps to `model`, sharding
    # the S axis of attention (scores stay local; av partial-sums reduce)
    k = shard(k, "batch", "kv_seq", kv_ax, None)
    v = shard(v, "batch", "kv_seq", kv_ax, None)

    S = k.shape[1]
    qg = q.reshape(B, T, K, G, Dh)
    q_pos = positions if positions.ndim else positions[None]
    kv_pos = jnp.arange(S)

    def attend(qc, qp):
        """One query block against the full K/V.  Softmax over the whole S
        axis is computed inside the block, so chunking is exact (the
        flash-attention tiling insight, without needing the online pass
        because S stays resident)."""
        scores = jnp.einsum("btkgd,bskd->bkgts", qc, k) / math.sqrt(Dh)
        if causal:
            mask = qp[..., :, None] >= kv_pos[None, :]
            while mask.ndim < scores.ndim:
                mask = jnp.expand_dims(mask, -3 if mask.ndim >= 2 else 0)
        else:
            mask = None
        w = softmax_fp32(scores, mask).astype(cd)
        return jnp.einsum("bkgts,bskd->btkgd", w, v)

    qc_len = cfg.q_chunk
    if T > qc_len and T % qc_len == 0 and q_pos.ndim == 1:
        nc = T // qc_len
        qs = jnp.moveaxis(qg.reshape(B, nc, qc_len, K, G, Dh), 1, 0)
        ps = q_pos.reshape(nc, qc_len)
        _, outs = jax.lax.scan(
            lambda _, xs: (None, attend(xs[0], xs[1])), None, (qs, ps))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, T, H * Dh)
    else:
        out = attend(qg, q_pos).reshape(B, T, H * Dh)
    out = shard(out, "batch", "seq", "heads")
    out = jnp.einsum("bth,hd->btd", out, p["wo"].astype(cd),
                     preferred_element_type=cd)  # bf16 wire: cross-shard
    return shard(out, "batch", "seq", "d_model"), new_cache  # partial sums reduce in bf16


def init_cross_attention(b: ParamBuilder, cfg: ModelConfig, L: int,
                         prefix: str = "xattn"):
    init_attention(b, cfg, L, prefix=prefix)


def cross_attention(cfg: ModelConfig, p, x, mem_k, mem_v):
    """Whisper-style cross attention over precomputed encoder memory.
    mem_k/mem_v: (B, S_enc, K, Dh) (already projected + cached)."""
    a = cfg.attn
    H, K, Dh = a.n_heads, a.n_kv, a.head_dim
    G = H // K
    B, T, D = x.shape
    cd = cfg.cdtype
    q = jnp.einsum("btd,dh->bth", x, p["wq"].astype(cd)).reshape(B, T, H, Dh)
    qg = q.reshape(B, T, K, G, Dh)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, mem_k.astype(cd)) / math.sqrt(Dh)
    w = softmax_fp32(scores).astype(cd)
    out = jnp.einsum("bkgts,bskd->btkgd", w, mem_v.astype(cd))
    out = out.reshape(B, T, H * Dh)
    return jnp.einsum("bth,hd->btd", out, p["wo"].astype(cd))


def project_memory(cfg: ModelConfig, p, enc_out):
    """Precompute cross-attn K/V from encoder output (prefill-time)."""
    a = cfg.attn
    K, Dh = a.n_kv, a.head_dim
    B, S, D = enc_out.shape
    cd = cfg.cdtype
    mk = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"].astype(cd)).reshape(B, S, K, Dh)
    mv = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"].astype(cd)).reshape(B, S, K, Dh)
    return mk, mv


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(b: ParamBuilder, cfg: ModelConfig, L: int, d_ff: int | None = None,
             prefix: str = "mlp", gated: bool = True):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    s = b.sub(prefix)
    if gated:
        s.make("wi_g", (L, D, F), ("layers", "d_model", "ffn"))
    s.make("wi", (L, D, F), ("layers", "d_model", "ffn"))
    s.make("wo", (L, F, D), ("layers", "ffn", "d_model"))


def mlp(cfg: ModelConfig, p, x, gated: bool = True):
    cd = cfg.cdtype
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(cd))
    if gated:
        g = jnp.einsum("btd,df->btf", x, p["wi_g"].astype(cd))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = shard(h, "batch", "seq", "ffn")
    out = jnp.einsum("btf,fd->btd", h, p["wo"].astype(cd),
                     preferred_element_type=cd)
    return shard(out, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------

def init_embed(b: ParamBuilder, cfg: ModelConfig):
    b.make("embed", (cfg.vocab, cfg.d_model), ("vocab", "d_model"),
           init="embed")
    if not cfg.tie_embeddings:
        b.make("lm_head", (cfg.vocab, cfg.d_model), ("vocab", "d_model"))
    b.make("final_norm", (cfg.d_model,), ("d_model",), init="ones")


def embed_tokens(cfg: ModelConfig, params, tokens):
    emb = params["embed"].astype(cfg.cdtype)
    x = jnp.take(emb, tokens, axis=0)
    return shard(x, "batch", "seq", "d_model")


def logits_from_hidden(cfg: ModelConfig, params, x):
    w = params.get("lm_head", params["embed"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,vd->btv", x.astype(jnp.float32),
                        w.astype(jnp.float32))
    return shard(logits, "batch", "seq", "vocab")


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token CE in fp32; labels == ignore_id are masked out."""
    valid = labels != ignore_id
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def remat_policy(name: str):
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return None
