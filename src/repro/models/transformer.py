"""Generic decoder-only transformer (dense / MoE / MLA) with scanned layer
stacks.

Layers are organized into *segments*: (n_steps, ffn_kinds) where each scan
step applies len(ffn_kinds) consecutive layers (attention + that FFN kind).
This expresses llama4's interleaved dense/MoE (24 steps of ("dense",
"moe")), deepseek's leading dense layer ((1, ("dense",)) + (26, ("moe",))),
and plain stacks ((L, ("dense",))) with a single scan body each — keeping
the HLO small enough to compile 126-layer models in the dry-run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..runtime.sharding import shard
from . import layers as ly
from .mla import init_mla, mla_attention
from .moe import init_moe, moe_block


def segments_of(cfg: ModelConfig) -> list[tuple[int, tuple[str, ...]]]:
    g = max(1, cfg.layers_per_step)
    mo = cfg.moe
    if mo is None:
        if cfg.n_layers % g:
            g = 1
        return [(cfg.n_layers // g, ("dense",) * g)]
    segs = []
    if mo.first_dense:
        segs.append((mo.first_dense, ("dense",)))
    rest = cfg.n_layers - mo.first_dense
    if mo.period > 1:
        assert rest % mo.period == 0
        kinds = tuple("dense" if (j % mo.period) != mo.period - 1 else "moe"
                      for j in range(mo.period))
        # group g periods per scan step when divisible
        n_steps = rest // mo.period
        if g > 1 and n_steps % g == 0:
            kinds = kinds * g
            n_steps //= g
        segs.append((n_steps, kinds))
    else:
        n_steps = rest
        if g > 1 and rest % g == 0:
            n_steps = rest // g
            segs.append((n_steps, ("moe",) * g))
        else:
            segs.append((rest, ("moe",)))
    return segs


def _init_block(b: ly.ParamBuilder, cfg: ModelConfig, L: int, kind: str,
                idx: int):
    s = b.sub(f"l{idx}")
    s.make("ln_attn", (L, cfg.d_model), ("layers", "d_model"), init="ones")
    s.make("ln_mlp", (L, cfg.d_model), ("layers", "d_model"), init="ones")
    if cfg.mla is not None:
        init_mla(s, cfg, L)
    else:
        ly.init_attention(s, cfg, L)
    if kind == "moe":
        init_moe(s, cfg, L)
    else:
        ly.init_mlp(s, cfg, L)


def init_params(cfg: ModelConfig, rng):
    b = ly.ParamBuilder(rng, cfg.pdtype)
    ly.init_embed(b, cfg)
    for si, (n, kinds) in enumerate(segments_of(cfg)):
        seg = b.sub(f"seg{si}")
        for j, kind in enumerate(kinds):
            _init_block(seg, cfg, n, kind, j)
    return b.params, b.specs


def _apply_block(cfg: ModelConfig, p, kind: str, x, positions, cache,
                 cache_pos):
    h = ly.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
    if cfg.mla is not None:
        att, new_cache = mla_attention(cfg, p["attn"], h, positions,
                                       cache=cache, cache_pos=cache_pos)
    else:
        att, new_cache = ly.attention(cfg, p["attn"], h, positions,
                                      cache=cache, cache_pos=cache_pos)
    x = x + att
    h = ly.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind == "moe":
        out, aux = moe_block(cfg, p["moe"], h)
    else:
        out = ly.mlp(cfg, p["mlp"], h)
    return x + out, new_cache, aux


def backbone(cfg: ModelConfig, params, x, positions, caches=None,
             cache_pos=None):
    """x: (B,T,D) hidden.  caches: None or {segK: {lJ: {k,v|ckv,kr}: (n,...)}}
    Returns (hidden, new_caches, aux_loss)."""
    policy = ly.remat_policy(cfg.remat)
    new_caches = {} if caches is not None else None
    aux_total = jnp.zeros((), jnp.float32)

    for si, (n, kinds) in enumerate(segments_of(cfg)):
        seg_p = params[f"seg{si}"]
        seg_c = caches.get(f"seg{si}") if caches is not None else None

        def step(carry, xs, kinds=kinds):
            h, aux = carry
            layer_p, layer_c = xs
            new_c = {}
            for j, kind in enumerate(kinds):
                cj = layer_c.get(f"l{j}") if layer_c is not None else None
                h, nc, a = _apply_block(cfg, layer_p[f"l{j}"], kind, h,
                                        positions, cj, cache_pos)
                if nc is not None:
                    new_c[f"l{j}"] = nc
                aux = aux + a
            return (h, aux), new_c

        step_fn = step
        # remat only matters under grad; inference graphs skip it (a
        # rematerialized prefill hoists f32 converts for nothing — §Perf B2)
        if policy is not None and caches is None:
            step_fn = jax.checkpoint(step, policy=policy,
                                     prevent_cse=False)

        (x, aux_total), seg_new = jax.lax.scan(
            step_fn, (x, aux_total), (seg_p, seg_c))
        if new_caches is not None:
            new_caches[f"seg{si}"] = seg_new
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch):
    tokens = batch["tokens"]
    labels = batch["labels"]
    x = ly.embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    x, _, aux = backbone(cfg, params, x, positions)
    logits = ly.logits_from_hidden(cfg, params, x)
    return ly.cross_entropy(logits, labels) + aux


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=None):
    """Zeroed KV cache pytree matching backbone()'s expectations."""
    dtype = dtype or cfg.cdtype
    caches = {}
    for si, (n, kinds) in enumerate(segments_of(cfg)):
        seg = {}
        for j in range(len(kinds)):
            if cfg.mla is not None:
                m = cfg.mla
                seg[f"l{j}"] = {
                    "ckv": jnp.zeros((n, batch, seq_len, m.kv_lora), dtype),
                    "kr": jnp.zeros((n, batch, seq_len, m.rope_dim), dtype),
                }
            else:
                a = cfg.attn
                seg[f"l{j}"] = {
                    "k": jnp.zeros((n, batch, seq_len, a.n_kv, a.head_dim), dtype),
                    "v": jnp.zeros((n, batch, seq_len, a.n_kv, a.head_dim), dtype),
                }
        caches[f"seg{si}"] = seg
    return caches


def cache_specs(cfg: ModelConfig):
    """Logical axes for cache leaves (mirrors init_cache)."""
    def leaf(_):
        return ("layers", "batch", "kv_seq", "kv_heads", "head_dim")

    def leaf_mla(name):
        return ("layers", "batch", "kv_seq", "kv_lora")

    specs = {}
    for si, (n, kinds) in enumerate(segments_of(cfg)):
        seg = {}
        for j in range(len(kinds)):
            if cfg.mla is not None:
                seg[f"l{j}"] = {"ckv": leaf_mla("ckv"), "kr": leaf_mla("kr")}
            else:
                seg[f"l{j}"] = {"k": leaf("k"), "v": leaf("v")}
        specs[f"seg{si}"] = seg
    return specs


def prefill(cfg: ModelConfig, params, tokens, cache):
    """Fill the cache with T prompt tokens; returns (last_logits, cache)."""
    x = ly.embed_tokens(cfg, params, tokens)
    positions = jnp.arange(tokens.shape[1])
    x, new_caches, _ = backbone(cfg, params, x, positions, caches=cache,
                                cache_pos=0)
    logits = ly.logits_from_hidden(cfg, params, x[:, -1:, :])
    return logits[:, 0], new_caches


def decode_step(cfg: ModelConfig, params, tokens, cache, pos):
    """One token per sequence.  tokens: (B,) int32; pos: scalar cache index.
    Returns (logits (B, V), new_cache)."""
    x = ly.embed_tokens(cfg, params, tokens[:, None])
    positions = pos[None] if hasattr(pos, "ndim") else jnp.asarray([pos])
    x, new_caches, _ = backbone(cfg, params, x, positions, caches=cache,
                                cache_pos=pos)
    logits = ly.logits_from_hidden(cfg, params, x)
    return logits[:, 0], new_caches
