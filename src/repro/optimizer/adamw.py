"""Pure-pytree AdamW + Adafactor with large-scale options.

No optax dependency.  Features used by the distributed runtime:
  * state dtype control (fp32 default; bf16 m/v for ZeRO-friendly memory —
    used by the llama3-405b config to fit a v5e pod),
  * global-norm gradient clipping,
  * decoupled weight decay,
  * works under jit/pjit: state is a pytree that inherits param shardings
    (see runtime/sharding.py for ZeRO placement over the data axis).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0
    state_dtype: Any = jnp.float32

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return (new_p.astype(p.dtype), m32.astype(self.state_dtype),
                    v32.astype(self.state_dtype))

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, AdamWState(step=step, m=new_m, v=new_v)


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row second-moment (for matrices) or full v (for vectors)
    vc: Any   # col second-moment (zeros for vectors)


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second moments — O(n+m) state for an (n,m) matrix.  The
    memory-saving optimizer option for the 400B-class configs."""
    lr: float | Callable = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def init(self, params):
        def vr(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(vr, params),
                              vc=jax.tree.map(vc, params))

    def update(self, grads, state, params):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-self.decay)
        lr = self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

        def upd(g, vr, vc, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if p.ndim >= 2:
                nvr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                nvc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                r = nvr / jnp.maximum(nvr.mean(axis=-1, keepdims=True), self.eps)
                approx = r[..., None] * nvc[..., None, :]
                u = g32 * jax.lax.rsqrt(jnp.maximum(approx, self.eps))
            else:
                nvr = beta * vr + (1 - beta) * g2
                nvc = vc
                u = g32 * jax.lax.rsqrt(jnp.maximum(nvr, self.eps))
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            new_p = p.astype(jnp.float32) - lr * u
            return new_p.astype(p.dtype), nvr, nvc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_ratio: float = 0.1) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(step < warmup, warm, cos)
    return sched
