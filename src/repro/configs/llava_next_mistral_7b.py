"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000; anyres tiling stubbed — input_specs()
provides 576 precomputed patch embeddings at d_model (one base-resolution
tile; the vision tower + projector are the assignment-mandated stub).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    d_ff=14_336,
    vocab=32_000,
    attn=AttnConfig(n_heads=32, n_kv=8, head_dim=128, rope_theta=1_000_000.0),
    n_img_tokens=576,
    tie_embeddings=False,
    param_dtype="bfloat16",
    remat="dots",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=160, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=2, head_dim=16),
        n_img_tokens=16,
        param_dtype="float32", remat="none")
