"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936, qk-norm.  [hf:Qwen/Qwen3-8B; hf]"""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    d_ff=3072,
    vocab=151_936,
    attn=AttnConfig(n_heads=16, n_kv=8, head_dim=128, qk_norm=True,
                    rope_theta=1_000_000.0),
    tie_embeddings=True,
    param_dtype="bfloat16",
    remat="dots",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=160, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=2, head_dim=16, qk_norm=True),
        param_dtype="float32", remat="none")
