"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx, head_dim=128 (explicit, != d_model/n_heads).
[hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    d_ff=14_336,
    vocab=131_072,
    attn=AttnConfig(n_heads=32, n_kv=8, head_dim=128, rope_theta=1_000_000.0),
    tie_embeddings=False,
    param_dtype="bfloat16",
    remat="dots",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=160, vocab=512,
        attn=AttnConfig(n_heads=8, n_kv=2, head_dim=16),
        param_dtype="float32", remat="none")
