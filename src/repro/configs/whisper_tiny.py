"""whisper-tiny [audio] — enc-dec, 4L+4L d_model=384 6H d_ff=1536
vocab=51865; conv frontend stubbed — input_specs() provides precomputed
frame embeddings (B, 1500, 384).  [arXiv:2212.04356; unverified]"""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    d_ff=1536,
    vocab=51_865,
    attn=AttnConfig(n_heads=6, n_kv=6, head_dim=64, rope_theta=10_000.0),
    enc_layers=4,
    enc_frames=1500,
    tie_embeddings=True,
    param_dtype="float32",
    remat="none",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=4, head_dim=16),
        enc_layers=2, enc_frames=64)
