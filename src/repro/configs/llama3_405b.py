"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783; unverified]

Fitting a v5e pod (16 GB HBM): bf16 params + bf16 Adam moments
(opt_state_dtype) + full remat + gradient accumulation (ACCUM_STEPS in
launch/dryrun).  See EXPERIMENTS.md §Dry-run for the memory analysis."""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    d_ff=53_248,
    vocab=128_256,
    attn=AttnConfig(n_heads=128, n_kv=8, head_dim=128, rope_theta=500_000.0),
    tie_embeddings=False,
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
    remat="full",
    fsdp=True,
    layers_per_step=6,   # 21 scan steps: saved-residual stack /6 at equal recompute
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=192, vocab=512,
        attn=AttnConfig(n_heads=8, n_kv=2, head_dim=16),
        param_dtype="float32", opt_state_dtype="float32", remat="none")
