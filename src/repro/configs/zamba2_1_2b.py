"""zamba2-1.2b [hybrid] — 38L d_model=2048, Mamba-2 backbone (ssm_state=64)
+ shared attention block (32H MHA kv=32, d_ff=8192) applied every 6
layers.  Sub-quadratic backbone: runs long_500k.  [arXiv:2411.15242; hf]"""
from .base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    d_ff=8192,
    vocab=32_000,
    attn=AttnConfig(n_heads=32, n_kv=32, head_dim=64, rope_theta=10_000.0),
    ssm=SSMConfig(state=64, conv=4, expand=2, headdim=64, chunk=256),
    shared_attn_period=6,
    tie_embeddings=True,
    param_dtype="bfloat16",
    remat="dots",
    sub_quadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=4, head_dim=16),
        ssm=SSMConfig(state=16, conv=4, expand=2, headdim=16, chunk=32),
        shared_attn_period=2,
        param_dtype="float32", remat="none")
