"""qwen2-1.5b [dense] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936, QKV bias.  [arXiv:2407.10671; hf]"""
from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    d_ff=8960,
    vocab=151_936,
    attn=AttnConfig(n_heads=12, n_kv=2, head_dim=128, qkv_bias=True,
                    rope_theta=1_000_000.0),
    tie_embeddings=True,
    param_dtype="bfloat16",
    remat="dots",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=160, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=2, head_dim=16, qkv_bias=True),
        param_dtype="float32", remat="none")
