"""Config system: model/shape/run dataclasses + the architecture registry.

Every assigned architecture gets one module in this package defining
`CONFIG: ModelConfig` with the exact published numbers, plus a
`reduced()` variant for CPU smoke tests.  Shapes are the four assigned
input-shape cells; `kind` selects which step function the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0


@dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    nope_dim: int = 128
    rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_ff: int
    n_shared: int = 0          # shared experts (deepseek) — folded dense ff
    period: int = 1            # MoE layer every `period` layers (llama4: 2)
    first_dense: int = 0       # leading dense layers (deepseek: 1)
    group_size: int = 2048     # GShard dispatch group size (perf knob)
    capacity_factor: float = 1.25
    aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state: int = 128
    conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_period: int = 0     # zamba2: shared attn block every N layers
    n_img_tokens: int = 0           # llava: stubbed patch embeddings
    enc_layers: int = 0             # whisper encoder depth
    enc_frames: int = 1500          # whisper encoder frames (stub)
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: str = "none"             # none|dots|full
    sub_quadratic: bool = False     # can run long_500k
    fsdp: bool = False              # shard params over `data` too (ZeRO-3)
    q_chunk: int = 2048             # query-chunked attention block (exact;
                                    # caps score temp at chunk x S)
    layers_per_step: int = 1        # layers per scan step: under full remat
                                    # the saved residual stack shrinks by
                                    # this factor at equal recompute
    notes: str = ""

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS) ---------------------
    def param_counts(self) -> dict:
        """Returns dict(total=..., active=...) approximate param counts."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        total = active = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
            active += V * D
        for layer in range(L):
            lt = lp = 0
            if self.family in ("ssm",) or (
                    self.family == "hybrid"
                    and not self._is_shared_attn_layer(layer)):
                s = self.ssm
                d_in = s.expand * D
                nh = d_in // s.headdim
                proj_in = D * (2 * d_in + 2 * s.state + nh)
                conv = (d_in + 2 * s.state) * s.conv
                lt = proj_in + conv + 3 * nh + d_in + d_in * D
                lp = lt
            else:
                a = self.attn
                if self.mla is not None:
                    m = self.mla
                    h = a.n_heads
                    qd = h * (m.nope_dim + m.rope_dim)
                    attn_p = D * qd + D * (m.kv_lora + m.rope_dim) \
                        + m.kv_lora * h * (m.nope_dim + m.v_dim) \
                        + h * m.v_dim * D
                else:
                    attn_p = D * a.n_heads * a.head_dim * 2 \
                        + D * a.n_kv * a.head_dim * 2
                if self.moe is not None and self._is_moe_layer(layer):
                    mo = self.moe
                    ff_t = mo.n_experts * 3 * D * mo.expert_ff \
                        + mo.n_shared * 3 * D * mo.expert_ff + D * mo.n_experts
                    ff_a = (mo.top_k + mo.n_shared) * 3 * D * mo.expert_ff \
                        + D * mo.n_experts
                else:
                    ff_t = ff_a = 3 * D * F
                lt = attn_p + ff_t
                lp = attn_p + ff_a
            total += lt
            active += lp
        # whisper encoder
        if self.enc_layers:
            a = self.attn
            enc = self.enc_layers * (D * a.n_heads * a.head_dim * 4
                                     + 2 * D * F)
            total += enc
            active += enc
        return dict(total=int(total), active=int(active))

    def _is_moe_layer(self, layer: int) -> bool:
        mo = self.moe
        if mo is None or layer < mo.first_dense:
            return False
        return (layer - mo.first_dense) % mo.period == mo.period - 1 \
            if mo.period > 1 else True

    def _is_shared_attn_layer(self, layer: int) -> bool:
        p = self.shared_attn_period
        return p > 0 and (layer % p) == p - 1


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train|prefill|decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# per-(arch, shape) microbatching for the train shape: global_batch is
# split into `accum` sequential microbatches to bound live activations.
ACCUM_STEPS: dict[tuple[str, str], int] = {}


def accum_for(arch: str, shape: str, default: int = 1) -> int:
    return ACCUM_STEPS.get((arch, shape), default)


@dataclass
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    accum: int = 1
    habf_gate: bool = False        # fuse HABF admission probe into serving
    rules: Optional[dict] = None   # logical sharding rule override
