"""mamba2-780m [ssm] — 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  Sub-quadratic: runs long_500k.
[arXiv:2405.21060; unverified]"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    d_ff=0,
    vocab=50_280,
    ssm=SSMConfig(state=128, conv=4, expand=2, headdim=64, chunk=256),
    tie_embeddings=True,
    param_dtype="bfloat16",
    remat="dots",
    sub_quadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, vocab=512,
        ssm=SSMConfig(state=16, conv=4, expand=2, headdim=16, chunk=32),
        param_dtype="float32", remat="none")
