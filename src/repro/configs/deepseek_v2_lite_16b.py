"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400, MoE 64e top-6, 2 shared experts, MLA kv_lora=512.
[arXiv:2405.04434; hf]

Assignment note: the assignment line reads "2 shared+160 routed top-6";
the published V2-Lite config is 64 routed + 2 shared (160 routed is
DeepSeek-V2 full).  We follow the assignment's "MoE 64e top-6" with
2 shared experts and note the discrepancy here.  First layer is dense
(d_ff 10944) per the published config."""
from .base import AttnConfig, MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    d_ff=10_944,                      # first dense layer FFN
    vocab=102_400,
    attn=AttnConfig(n_heads=16, n_kv=16, head_dim=128, rope_theta=10_000.0),
    mla=MLAConfig(kv_lora=512, nope_dim=128, rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, expert_ff=1408, n_shared=2,
                  period=1, first_dense=1, group_size=2048,
                  capacity_factor=1.25),
    tie_embeddings=False,
    param_dtype="bfloat16",
    remat="dots",
    notes="MLA latent KV cache (512+64 per token, vs 16*128*2 for GQA).",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=4, n_kv=4, head_dim=16),
        mla=MLAConfig(kv_lora=32, nope_dim=16, rope_dim=8, v_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, expert_ff=64, n_shared=1,
                      period=1, first_dense=1, group_size=64,
                      capacity_factor=1.5),
        param_dtype="float32", remat="none")
