"""Architecture registry: one module per assigned architecture."""
from .base import (ModelConfig, ShapeConfig, AttnConfig, MoEConfig,
                   MLAConfig, SSMConfig, SHAPES, RunConfig, accum_for)
from . import (llama4_maverick_400b_a17b, deepseek_v2_lite_16b,
               mistral_nemo_12b, llama3_405b, qwen2_1_5b, qwen3_0_6b,
               mamba2_780m, zamba2_1_2b, llava_next_mistral_7b, whisper_tiny)

_MODULES = (llama4_maverick_400b_a17b, deepseek_v2_lite_16b,
            mistral_nemo_12b, llama3_405b, qwen2_1_5b, qwen3_0_6b,
            mamba2_780m, zamba2_1_2b, llava_next_mistral_7b, whisper_tiny)

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
REDUCED: dict[str, ModelConfig] = {m.CONFIG.name: m.reduced()
                                   for m in _MODULES}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    table = REDUCED if reduced else ARCHS
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]
