"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, interleaved dense/MoE layers +
shared expert (early fusion; text shapes only per assignment).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab=202_048,
    attn=AttnConfig(n_heads=40, n_kv=8, head_dim=128, rope_theta=500_000.0),
    moe=MoEConfig(n_experts=128, top_k=1, expert_ff=8192, n_shared=1,
                  period=2, group_size=4096, capacity_factor=1.25),
    tie_embeddings=False,
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",   # 400B total params: bf16 Adam state to fit
    remat="full",
    fsdp=True,
    notes=("Interleaved dense/MoE every other layer (period=2); one shared "
           "expert per MoE layer. 400B total / ~17B active."),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, d_ff=128, vocab=512,
        attn=AttnConfig(n_heads=8, n_kv=4, head_dim=8),
        moe=MoEConfig(n_experts=4, top_k=1, expert_ff=128, n_shared=1,
                      period=2, group_size=64, capacity_factor=1.5),
        param_dtype="float32", opt_state_dtype="float32", remat="none")
