"""Global hash-function family H for HABF.

The paper uses |H| = 22 named C string hashers (xxHash, CityHash, ...).
TPU adaptation (DESIGN.md §3): keys are fingerprinted to 64 bits on the
host once; the global family H is a parameterized collection of 32-bit
mixers (murmur3/xxhash-style finalizers with per-function odd multipliers
and seeds).  All arithmetic is uint32 so the *same* function is computed
by numpy on the host (construction) and by jnp / Pallas on the device
(query) — the two must agree bit-exactly.

Range reduction uses Lemire fastrange ``(h * m) >> 32`` instead of a
modulo: TPUs have no cheap integer divide, and fastrange is exactly
uniform for uniform h.  Host and device both use it, so indices agree.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Number of global hash functions |H| (paper §V-D3: 22 functions,
# cell size alpha=5 bits => up to 31 representable; index 0 is reserved
# for "empty" in HashExpressor cells, so hash indices are stored 1-based).
DEFAULT_N_HASH = 22

_M32 = np.uint32(0xFFFFFFFF)

# Distinct odd multipliers / seeds per hash function, generated once from
# splitmix64 so the family is deterministic and reproducible.


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


def make_family(n_hash: int = DEFAULT_N_HASH, seed: int = 0x5EED):
    """Returns dict of uint32 constant arrays of shape (n_hash,)."""
    c1, c2, mul = [], [], []
    x = seed
    for _ in range(n_hash):
        x = _splitmix64(x)
        c1.append(x & 0xFFFFFFFF)
        x = _splitmix64(x)
        c2.append(x & 0xFFFFFFFF)
        x = _splitmix64(x)
        mul.append((x | 1) & 0xFFFFFFFF)  # odd multiplier
    return {
        "c1": np.asarray(c1, np.uint32),
        "c2": np.asarray(c2, np.uint32),
        "mul": np.asarray(mul, np.uint32),
    }


FAMILY = make_family()


# --------------------------------------------------------------------------
# numpy (host) side
# --------------------------------------------------------------------------

def _mix32_np(x: np.ndarray) -> np.ndarray:
    """murmur3-style 32-bit finalizer (numpy uint32, wraparound intended)."""
    with np.errstate(over="ignore"):
        x = x.astype(np.uint32)
        x ^= x >> np.uint32(16)
        x = (x * np.uint32(0x7FEB352D)) & _M32
        x ^= x >> np.uint32(15)
        x = (x * np.uint32(0x846CA68B)) & _M32
        x ^= x >> np.uint32(16)
    return x


def hash_value_np(keys_u64: np.ndarray, hash_idx, family=FAMILY) -> np.ndarray:
    """32-bit hash values.  keys_u64: (...,) uint64.  hash_idx: int array,
    broadcast against keys.  Returns uint32 with shape broadcast(keys, idx)."""
    keys_u64 = np.asarray(keys_u64, np.uint64)
    hash_idx = np.asarray(hash_idx, np.int64)
    lo = (keys_u64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (keys_u64 >> np.uint64(32)).astype(np.uint32)
    c1 = family["c1"][hash_idx]
    c2 = family["c2"][hash_idx]
    mu = family["mul"][hash_idx]
    x = _mix32_np(lo ^ c1)
    y = _mix32_np(hi ^ c2)
    with np.errstate(over="ignore"):
        h = (x * mu + (y ^ np.uint32(0x9E3779B9))) & _M32
    return _mix32_np(h)


def fastrange_np(h: np.ndarray, m: int) -> np.ndarray:
    """Lemire fastrange: uniform map uint32 -> [0, m)."""
    return ((h.astype(np.uint64) * np.uint64(m)) >> np.uint64(32)).astype(np.int64)


def hash_index_np(keys_u64, hash_idx, m: int, family=FAMILY) -> np.ndarray:
    return fastrange_np(hash_value_np(keys_u64, hash_idx, family), m)


def double_hash_value_np(keys_u64: np.ndarray, i, family=FAMILY) -> np.ndarray:
    """f-HABF double hashing (Kirsch–Mitzenmacher): g_i = h_a + i * h_b."""
    i = np.asarray(i, np.uint32)
    ha = hash_value_np(keys_u64, 0, family)
    hb = hash_value_np(keys_u64, 1, family) | np.uint32(1)
    with np.errstate(over="ignore"):
        return (ha + i * hb) & _M32


# --------------------------------------------------------------------------
# jnp (device) side — must agree bit-exactly with the numpy side
# --------------------------------------------------------------------------

def _mix32_jnp(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def hash_value_jnp(key_lo, key_hi, c1, c2, mul):
    """key_lo/key_hi: uint32 arrays; c1/c2/mul: broadcastable uint32."""
    x = _mix32_jnp(key_lo ^ c1)
    y = _mix32_jnp(key_hi ^ c2)
    h = x * mul + (y ^ jnp.uint32(0x9E3779B9))
    return _mix32_jnp(h)


def umulhi32_jnp(a, b):
    """High 32 bits of a*b via 16-bit limbs (uint32 only, TPU-friendly)."""
    a = a.astype(jnp.uint32)
    b = jnp.uint32(b) if np.isscalar(b) else b.astype(jnp.uint32)
    a_lo = a & 0xFFFF
    a_hi = a >> 16
    b_lo = b & 0xFFFF
    b_hi = b >> 16
    t0 = a_lo * b_lo
    t1 = a_lo * b_hi + (t0 >> 16)
    t2 = a_hi * b_lo + (t1 & 0xFFFF)
    return a_hi * b_hi + (t1 >> 16) + (t2 >> 16)


def fastrange_jnp(h, m: int):
    return umulhi32_jnp(h, np.uint32(m)).astype(jnp.int32)


def hash_index_jnp(key_lo, key_hi, c1, c2, mul, m: int):
    return fastrange_jnp(hash_value_jnp(key_lo, key_hi, c1, c2, mul), m)


def split_u64(keys_u64: np.ndarray):
    """Host-side split of uint64 keys into device-friendly (lo, hi) uint32."""
    keys_u64 = np.asarray(keys_u64, np.uint64)
    lo = (keys_u64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (keys_u64 >> np.uint64(32)).astype(np.uint32)
    return lo, hi


# --------------------------------------------------------------------------
# byte-string fingerprinting (host only): vectorized FNV-1a 64
# --------------------------------------------------------------------------

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


def as_u64_keys(keys) -> np.ndarray:
    """Normalize a key batch to (n,) uint64 fingerprints.

    Accepts an integer ndarray (any int dtype, reinterpreted as uint64), a
    list/tuple of str/bytes (FNV-1a fingerprinted), or a single str/bytes.
    This is the one key-normalization point shared by every `Filter`
    implementation, so host and device paths agree on key identity.
    """
    if isinstance(keys, np.ndarray):
        if keys.dtype.kind in "USO":      # string/bytes/object ndarray
            return fingerprint_bytes(list(keys.reshape(-1)))
        return keys.astype(np.uint64, copy=False).reshape(-1)
    if isinstance(keys, (str, bytes)):
        return fingerprint_bytes([keys])
    keys = list(keys)
    if keys and isinstance(keys[0], (str, bytes)):
        return fingerprint_bytes(keys)
    return np.asarray(keys, np.uint64).reshape(-1)


def as_str_keys(keys):
    """Return the string form of a key batch, or None if keys are already
    fingerprints (learned filters need the raw strings to featurize)."""
    if isinstance(keys, np.ndarray):
        if keys.dtype.kind in "USO":
            keys = list(keys.reshape(-1))
        else:
            return None
    elif isinstance(keys, (str, bytes)):
        return [keys]
    keys = list(keys)
    # an empty batch is a valid (empty) string batch
    if not keys or isinstance(keys[0], (str, bytes)):
        return keys
    return None


def fingerprint_bytes(keys: list) -> np.ndarray:
    """Vectorized FNV-1a(64) over a list of bytes/str.  One column pass per
    byte position — O(max_len) vector ops instead of a Python loop per key."""
    bs = [k.encode() if isinstance(k, str) else bytes(k) for k in keys]
    n = len(bs)
    if n == 0:
        return np.zeros((0,), np.uint64)
    lens = np.asarray([len(b) for b in bs], np.int64)
    max_len = max(1, int(lens.max()))
    mat = np.zeros((n, max_len), np.uint8)
    for i, b in enumerate(bs):
        if b:
            mat[i, : len(b)] = np.frombuffer(b, np.uint8)
    h = np.full((n,), _FNV_OFFSET, np.uint64)
    with np.errstate(over="ignore"):
        for j in range(max_len):
            valid = lens > j
            hv = (h ^ mat[:, j].astype(np.uint64)) * _FNV_PRIME
            h = np.where(valid, hv, h)
        # final avalanche so short keys spread over all 64 bits
        h ^= h >> np.uint64(33)
        h = (h * np.uint64(0xFF51AFD7ED558CCD)) & np.uint64(0xFFFFFFFFFFFFFFFF)
        h ^= h >> np.uint64(33)
    return h
