"""Synthetic stand-ins for the paper's datasets (offline container;
DESIGN.md §8):

  * shalla-like — URL-ish strings with evident structure (zipfian domain
    vocabulary, path segments), 50.9% positive / 49.1% negative split as
    in Shalla's Blacklists (1,491,178 / 1,435,527 at full scale).
  * ycsb-like   — 4-byte prefix + 64-bit integer, no structure
    (12,500,611 / 11,574,201 at full scale).

`scale` shrinks both proportionally for the CPU container.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .hashing import fingerprint_bytes

SHALLA_POS, SHALLA_NEG = 1_491_178, 1_435_527
YCSB_POS, YCSB_NEG = 12_500_611, 11_574_201


@dataclass
class KeySets:
    name: str
    pos_strs: list
    neg_strs: list
    pos_u64: np.ndarray
    neg_u64: np.ndarray

    @property
    def n_pos(self):
        return len(self.pos_u64)

    @property
    def n_neg(self):
        return len(self.neg_u64)


_TLDS = ["com", "net", "org", "io", "de", "cn", "ru", "info", "biz", "xxx"]
_WORDS = ["porn", "adult", "video", "cam", "free", "live", "hot", "chat",
          "game", "bet", "casino", "win", "shop", "cheap", "pill", "med",
          "news", "blog", "mail", "search", "photo", "file", "host", "link"]


def _urls(n: int, rng: np.random.Generator, salt: str) -> list:
    # zipf-weighted vocabulary -> "evident characteristics" like Shalla
    wp = 1.0 / np.arange(1, len(_WORDS) + 1)
    wp /= wp.sum()
    w1 = rng.choice(_WORDS, n, p=wp)
    w2 = rng.choice(_WORDS, n, p=wp)
    tld = rng.choice(_TLDS, n)
    num = rng.integers(0, 100_000, n)
    return [f"{a}{b}{salt}{c}.{t}/p{c % 97}" for a, b, c, t
            in zip(w1, w2, num, tld)]


def make_shalla(scale: float = 0.1, seed: int = 0) -> KeySets:
    rng = np.random.default_rng(seed)
    n_pos = max(1000, int(SHALLA_POS * scale))
    n_neg = max(1000, int(SHALLA_NEG * scale))
    # positives: blacklist domains; negatives: different salt namespace
    pos = _urls(n_pos, rng, salt="x")
    neg = _urls(n_neg, rng, salt="-ok")
    pos = list(dict.fromkeys(pos))
    negset = set(pos)
    neg = [u for u in dict.fromkeys(neg) if u not in negset]
    return KeySets("shalla", pos, neg,
                   fingerprint_bytes(pos), fingerprint_bytes(neg))


def make_ycsb(scale: float = 0.01, seed: int = 0) -> KeySets:
    rng = np.random.default_rng(seed + 1)
    n_pos = max(1000, int(YCSB_POS * scale))
    n_neg = max(1000, int(YCSB_NEG * scale))
    ids = rng.choice(np.uint64(1) << np.uint64(48), n_pos + n_neg,
                     replace=False)
    strs = [f"user{int(i):020d}" for i in ids]
    pos, neg = strs[:n_pos], strs[n_pos:]
    return KeySets("ycsb", pos, neg,
                   fingerprint_bytes(pos), fingerprint_bytes(neg))


def make_dataset(name: str, scale: float, seed: int = 0) -> KeySets:
    if name == "shalla":
        return make_shalla(scale, seed)
    if name == "ycsb":
        return make_ycsb(scale, seed)
    raise ValueError(name)
