"""Cost distributions (paper §V-C): Zipf with skewness theta in [0, 3],
randomly shuffled onto keys; theta = 0 degenerates to uniform."""
from __future__ import annotations

import numpy as np


def zipf_costs(n: int, skew: float, seed: int = 0,
               shuffle: bool = True) -> np.ndarray:
    """Zipf(skew) cost vector of length n, mean-normalized to 1."""
    if n == 0:
        return np.zeros((0,))
    if skew <= 0:
        return np.ones((n,))
    ranks = np.arange(1, n + 1, dtype=np.float64)
    c = ranks ** (-float(skew))
    c *= n / c.sum()
    if shuffle:
        rng = np.random.default_rng(seed)
        rng.shuffle(c)
    return c
