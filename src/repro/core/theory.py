"""Theoretical bounds from paper §IV (verified in benchmarks — Fig. 8)."""
from __future__ import annotations

import math


def bf_fpr(bits_per_key: float, k: int) -> float:
    """Classic Bloom-filter FPR (1 - e^{-k/b})^k (paper §II)."""
    return (1.0 - math.exp(-k / bits_per_key)) ** k


def p_xi_lower(bits_per_key: float, k: int) -> float:
    """Theorem 4.1: E[P_xi] > (k/b) / (e^{k/b} - 1)."""
    x = k / bits_per_key
    return x / (math.exp(x) - 1.0)


def p_s_lower(t: int, k: int, omega: int) -> float:
    """Eq. 11: insertion-success probability after t optimized keys."""
    return max(0.0, (1.0 - (k * t + k) / omega)) ** k


def expected_optimized_lower(T: int, p_c: float, k: int, omega: int) -> float:
    """Theorem 4.2 / Eq. 12: E[t] > T*P'_c*(omega - k^2)/(omega + T*P'_c*k^2)."""
    if omega <= k * k:
        return 0.0
    return T * p_c * (omega - k * k) / (omega + T * p_c * k * k)


def fbf_star_upper(fbf: float, T: int, p_c: float, k: int, omega: int,
                   n_neg: int) -> float:
    """Eq. 19: E[F*_bf] < E[F_bf] - E[t]/|O|."""
    return fbf - expected_optimized_lower(T, p_c, k, omega) / max(1, n_neg)


def habf_fpr_upper(fbf_star: float, t: int, omega: int) -> float:
    """§III-F: F_habf <= (omega + t)/omega * F*_bf."""
    return (omega + t) / omega * fbf_star
