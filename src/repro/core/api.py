"""Unified membership-filter contract (the paper's cross-filter comparison,
§V, made structural).

Every compared structure — HABF / f-HABF (the contribution), BF, double-
hashing BF, Xor, WBF, and the learned LBF/SLBF/Ada-BF family — answers the
same question: "is this key a member?"  This module pins that down:

  * ``SpaceBudget`` — the one space currency (total bytes; helpers for the
    paper's bits-per-key axis).
  * ``Filter`` — the protocol every filter implements:
    ``query(keys) -> bool (n,)``, ``size_bytes``, ``summary()``, and
    ``to_artifact()`` (typed pytree for the device query path, see
    ``repro.kernels.artifacts``).
  * a string registry: ``make_filter("habf", pos, neg, costs,
    space=SpaceBudget(...), seed=0)`` — one construction surface for
    examples, benchmarks, and serving.

Keys may be given as uint64 fingerprints or as raw strings/bytes
(fingerprinted via FNV-1a); learned filters additionally *require* the
string form to featurize.  ``costs`` is the per-negative-key false-positive
cost (the weighted-FPR objective); cost-weighted *insertion* (WBF) takes
``pos_costs=`` instead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Protocol, runtime_checkable

import numpy as np

from .hashing import as_str_keys, as_u64_keys


@dataclass(frozen=True)
class SpaceBudget:
    """Total space a filter may occupy (model + tables for learned ones)."""
    total_bytes: int

    @property
    def total_bits(self) -> int:
        return int(self.total_bytes) * 8

    @classmethod
    def from_bits_per_key(cls, bits_per_key: float, n_keys: int) -> "SpaceBudget":
        return cls(max(8, int(n_keys * bits_per_key) // 8))

    def bits_per_key(self, n_keys: int) -> float:
        return self.total_bits / max(1, n_keys)


@runtime_checkable
class Filter(Protocol):
    """The unified membership contract.

    ``query`` takes uint64 fingerprints or raw strings and returns a bool
    (n,) array with zero false negatives on the built positive set.
    ``to_artifact`` exports a typed, frozen, pytree-registered device
    artifact consumed by ``repro.kernels.query``.
    """

    def query(self, keys) -> np.ndarray: ...

    @property
    def size_bytes(self) -> float: ...

    def summary(self) -> dict: ...

    def to_artifact(self) -> Any: ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Filter]] = {}


def register_filter(name: str, builder: Callable[..., Filter] | None = None):
    """Register a builder under ``name`` (usable as a decorator).

    Builder signature: ``builder(pos_keys, neg_keys, costs, *, space, seed,
    **kw) -> Filter``.
    """
    def _register(fn):
        _REGISTRY[name] = fn
        return fn

    return _register(builder) if builder is not None else _register


def available_filters() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_filter(name: str, pos_keys, neg_keys=None, costs=None, *,
                space: SpaceBudget | int, seed: int = 0, **kw) -> Filter:
    """Build any registered filter through the unified surface.

    ``space`` may be a SpaceBudget or a raw byte count.  ``costs`` is the
    per-negative false-positive cost vector (ignored by cost-oblivious
    filters).
    """
    if name not in _REGISTRY:
        raise KeyError(f"unknown filter {name!r}; available: "
                       f"{', '.join(available_filters())}")
    if not isinstance(space, SpaceBudget):
        space = SpaceBudget(int(space))
    return _REGISTRY[name](pos_keys, neg_keys, costs, space=space, seed=seed,
                           **kw)


def _require_strs(name: str, keys):
    strs = as_str_keys(keys)
    if strs is None:
        raise TypeError(f"{name} is a learned filter and needs string keys "
                        "to featurize; pass the raw strings, not uint64 "
                        "fingerprints")
    return strs


# -- builders ---------------------------------------------------------------
# Imported lazily inside each builder so `core.api` stays importable from
# the class modules themselves (they import SpaceBudget/Filter for typing).

@register_filter("habf")
def _build_habf(pos, neg, costs, *, space, seed, **kw):
    from .habf import HABF
    return HABF.build(pos, neg, costs, space=space, seed=seed, **kw)


@register_filter("fhabf")
def _build_fhabf(pos, neg, costs, *, space, seed, **kw):
    from .habf import HABF
    kw.setdefault("fast", True)
    return HABF.build(pos, neg, costs, space=space, seed=seed, **kw)


@register_filter("bloom")
def _build_bloom(pos, neg, costs, *, space, seed, **kw):
    from .bloom import BloomFilter
    return BloomFilter.build(pos, neg, costs, space=space, seed=seed, **kw)


@register_filter("bloom-double")
def _build_bloom_double(pos, neg, costs, *, space, seed, **kw):
    from .bloom import DoubleHashBloomFilter
    return DoubleHashBloomFilter.build(pos, neg, costs, space=space,
                                       seed=seed, **kw)


@register_filter("xor")
def _build_xor(pos, neg, costs, *, space, seed, **kw):
    from .xor_filter import XorFilter
    return XorFilter.build(pos, neg, costs, space=space, seed=seed, **kw)


@register_filter("wbf")
def _build_wbf(pos, neg, costs, *, space, seed, **kw):
    from .wbf import WeightedBloomFilter
    return WeightedBloomFilter.build(pos, neg, costs, space=space, seed=seed,
                                     **kw)


@register_filter("lbf")
def _build_lbf(pos, neg, costs, *, space, seed, **kw):
    from .learned import build_lbf
    pos_strs = _require_strs("lbf", pos)
    neg_strs = _require_strs("lbf", neg)
    return build_lbf(pos_strs, as_u64_keys(pos), neg_strs, as_u64_keys(neg),
                     space.total_bytes, seed=seed, **kw)


@register_filter("slbf")
def _build_slbf(pos, neg, costs, *, space, seed, **kw):
    from .learned import build_lbf
    pos_strs = _require_strs("slbf", pos)
    neg_strs = _require_strs("slbf", neg)
    return build_lbf(pos_strs, as_u64_keys(pos), neg_strs, as_u64_keys(neg),
                     space.total_bytes, seed=seed, sandwich=True, **kw)


@register_filter("adabf")
def _build_adabf(pos, neg, costs, *, space, seed, **kw):
    from .learned import build_adabf
    pos_strs = _require_strs("adabf", pos)
    neg_strs = _require_strs("adabf", neg)
    return build_adabf(pos_strs, as_u64_keys(pos), neg_strs, as_u64_keys(neg),
                       space.total_bytes, seed=seed, **kw)
