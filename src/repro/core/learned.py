"""Learned-filter baselines (paper §II/§V-A2): LBF, Sandwiched LBF, Ada-BF.

Classifier: byte-level models in pure JAX matching the paper's sizes — a
16-dim character GRU or a 6-layer MLP over a 32-dim byte embedding —
trained in-framework with our AdamW (no Keras).  Keys are featurized from
their raw strings (truncated/padded to max_len bytes).

LBF   (Kraska'18):  score >= tau -> positive, else backup BF over the
                    positives the model missed.
SLBF  (Mitzenmacher'18): initial BF -> model -> backup BF.
AdaBF (Dai'19):     score buckets get decreasing hash counts k_j on one BF.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import hashing
from .bloom import BloomFilter
from ..optimizer.adamw import AdamW


MAX_LEN = 32


def encode_keys(keys: list, max_len: int = MAX_LEN) -> np.ndarray:
    """(n, max_len) uint8 byte matrix (0-padded)."""
    out = np.zeros((len(keys), max_len), np.uint8)
    for i, s in enumerate(keys):
        b = s.encode() if isinstance(s, str) else bytes(s)
        b = b[:max_len]
        out[i, : len(b)] = np.frombuffer(b, np.uint8)
    return out


# --------------------------------------------------------------------------
# models
# --------------------------------------------------------------------------

def init_mlp(key, embed_dim=32, hidden=32, n_layers=6):
    ks = jax.random.split(key, n_layers + 1)
    params = {"embed": jax.random.normal(ks[0], (256, embed_dim)) * 0.05}
    dims = [embed_dim] + [hidden] * (n_layers - 1) + [1]
    for i in range(n_layers):
        params[f"w{i}"] = (jax.random.normal(ks[i + 1], (dims[i], dims[i + 1]))
                           * (1.0 / np.sqrt(dims[i])))
        params[f"b{i}"] = jnp.zeros((dims[i + 1],))
    return params


def apply_mlp(params, bytes_mat):
    x = params["embed"][bytes_mat]                  # (n, L, e)
    mask = (bytes_mat > 0)[..., None]
    x = (x * mask).sum(1) / jnp.maximum(mask.sum(1), 1)
    i = 0
    while f"w{i}" in params:
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if f"w{i+1}" in params:
            x = jax.nn.relu(x)
        i += 1
    return x[..., 0]                                # logits


def init_gru(key, embed_dim=16, hidden=16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(hidden)
    return {
        "embed": jax.random.normal(k1, (256, embed_dim)) * 0.05,
        "wx": jax.random.normal(k2, (embed_dim, 3 * hidden)) * s,
        "wh": jax.random.normal(k3, (hidden, 3 * hidden)) * s,
        "b": jnp.zeros((3 * hidden,)),
        "wo": jax.random.normal(k4, (hidden, 1)) * s,
        "bo": jnp.zeros((1,)),
    }


def apply_gru(params, bytes_mat):
    x = params["embed"][bytes_mat]                  # (n, L, e)
    h0 = jnp.zeros((x.shape[0], params["wh"].shape[0]))

    def cell(h, xt):
        gates = xt @ params["wx"] + h @ params["wh"] + params["b"]
        r, z, n = jnp.split(gates, 3, axis=-1)
        r, z = jax.nn.sigmoid(r), jax.nn.sigmoid(z)
        n = jnp.tanh(n + r * (h @ params["wh"][:, : h.shape[-1]]))
        h = (1 - z) * n + z * h
        return h, None

    h, _ = jax.lax.scan(cell, h0, jnp.swapaxes(x, 0, 1))
    return (h @ params["wo"] + params["bo"])[..., 0]


def _model_bytes(params) -> int:
    return sum(np.prod(p.shape) * 4 for p in jax.tree.leaves(params))


def train_classifier(pos_strs, neg_strs, model: str = "mlp", seed: int = 0,
                     epochs: int = 3, batch: int = 1024, lr: float = 3e-3,
                     max_train: int = 60_000, min_steps: int = 200):
    """Returns (score_fn(strs)->np.float32 scores, model_bytes)."""
    rng = np.random.default_rng(seed)
    pos = list(pos_strs)
    neg = list(neg_strs)
    if len(pos) > max_train // 2:
        pos = [pos[i] for i in rng.choice(len(pos), max_train // 2, replace=False)]
    if len(neg) > max_train // 2:
        neg = [neg[i] for i in rng.choice(len(neg), max_train // 2, replace=False)]
    xs = encode_keys(pos + neg)
    ys = np.concatenate([np.ones(len(pos)), np.zeros(len(neg))]).astype(np.float32)

    key = jax.random.PRNGKey(seed)
    init, apply = ((init_mlp, apply_mlp) if model == "mlp"
                   else (init_gru, apply_gru))
    params = init(key)
    opt = AdamW(lr=lr, weight_decay=1e-4, clip_norm=1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, xb, yb):
        def loss_fn(p):
            logits = apply(p, xb)
            return jnp.mean(
                jnp.maximum(logits, 0) - logits * yb
                + jnp.log1p(jnp.exp(-jnp.abs(logits))))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    n = len(xs)
    steps_per_epoch = max(1, n // batch)
    epochs = max(epochs, int(np.ceil(min_steps / steps_per_epoch)))
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, max(1, n - batch + 1), batch):
            sel = perm[i : i + batch]
            params, state, _ = step(params, state, xs[sel], ys[sel])

    apply_j = jax.jit(apply)

    def score_fn(strs):
        mat = encode_keys(list(strs))
        out = []
        for i in range(0, len(mat), 65536):
            out.append(np.asarray(jax.nn.sigmoid(apply_j(params, mat[i:i + 65536]))))
        return np.concatenate(out) if out else np.zeros((0,), np.float32)

    # expose the trained weights so filters can export device artifacts
    score_fn.params = params
    score_fn.model_kind = model
    return score_fn, _model_bytes(params)


# --------------------------------------------------------------------------
# filters
# --------------------------------------------------------------------------

def _bf_for(keys_u64, budget_bytes, k_cap=16) -> BloomFilter:
    m = max(64, int(budget_bytes * 8))
    n = max(1, len(keys_u64))
    k = int(np.clip(round(np.log(2) * m / n), 1, k_cap))
    bf = BloomFilter(m, k)
    if len(keys_u64):
        bf.insert(np.asarray(keys_u64, np.uint64))
    return bf


def _norm_learned_keys(keys, keys_u64):
    """Accept the unified query(keys) form (strings, fingerprinted here)
    or the legacy query(strs, keys_u64) two-argument form."""
    if keys_u64 is not None:
        return list(keys), np.asarray(keys_u64, np.uint64)
    strs = hashing.as_str_keys(keys)
    if strs is None:
        raise TypeError("learned filters need string keys to featurize; "
                        "pass the raw strings, not uint64 fingerprints")
    return strs, hashing.as_u64_keys(strs)


@dataclass
class LearnedBloomFilter:
    score_fn: object
    tau: float
    backup: BloomFilter
    model_bytes: int
    pre: BloomFilter | None = None  # SLBF initial filter

    def query(self, keys, keys_u64=None) -> np.ndarray:
        strs, keys = _norm_learned_keys(keys, keys_u64)
        res = np.ones(len(keys), bool)
        if self.pre is not None:
            res &= self.pre.query(keys)
        s = self.score_fn(strs)
        model_pos = s >= self.tau
        backup_pos = self.backup.query(keys)
        return res & (model_pos | backup_pos)

    @property
    def size_bytes(self) -> float:
        b = self.model_bytes + self.backup.size_bytes
        if self.pre is not None:
            b += self.pre.size_bytes
        return b

    def summary(self) -> dict:
        return {"filter": "SLBF" if self.pre is not None else "LBF",
                "model_kind": getattr(self.score_fn, "model_kind", "?"),
                "model_bytes": self.model_bytes, "tau": float(self.tau),
                "backup_m_bits": self.backup.bits.m,
                "size_bytes": self.size_bytes}

    def to_artifact(self):
        from ..kernels.artifacts import LearnedArtifact
        return LearnedArtifact.from_arrays(
            params=self.score_fn.params,
            backup=self.backup.to_artifact(),
            pre=None if self.pre is None else self.pre.to_artifact(),
            model_kind=self.score_fn.model_kind, tau=float(self.tau))


def _choose_tau(pos_scores, neg_scores, backup_bytes):
    """Minimize fpr_tau + (1-fpr_tau)*backup_fpr over tau candidates."""
    best = (1.1, 0.5, None)
    for q in np.linspace(0.05, 0.995, 40):
        tau = float(np.quantile(neg_scores, q))
        fpr_tau = float((neg_scores >= tau).mean())
        n_fn = int((pos_scores < tau).sum())
        bpk = backup_bytes * 8.0 / max(1, n_fn)
        backup_fpr = 0.6185 ** bpk if n_fn else 0.0
        total = fpr_tau + (1 - fpr_tau) * backup_fpr
        if total < best[0]:
            best = (total, tau, None)
    return best[1]


def build_lbf(pos_strs, pos_u64, neg_strs, neg_u64, total_bytes,
              model="mlp", seed=0, sandwich=False) -> LearnedBloomFilter:
    score_fn, mbytes = train_classifier(pos_strs, neg_strs, model=model,
                                        seed=seed)
    budget = max(64, total_bytes - mbytes)
    pre = None
    pre_bytes = 0
    if sandwich:
        pre_bytes = budget // 3
        pre = _bf_for(pos_u64, pre_bytes)
        budget -= pre_bytes
    pos_scores = score_fn(pos_strs)
    neg_scores = score_fn(neg_strs)
    tau = _choose_tau(pos_scores, neg_scores, budget)
    fn_keys = np.asarray(pos_u64, np.uint64)[pos_scores < tau]
    backup = _bf_for(fn_keys, budget)
    return LearnedBloomFilter(score_fn=score_fn, tau=tau, backup=backup,
                              model_bytes=mbytes, pre=pre)


@dataclass
class AdaBF:
    score_fn: object
    taus: np.ndarray          # bucket edges (descending score), float32
    ks: np.ndarray            # hashes per bucket
    bf: BloomFilter
    model_bytes: int

    def _k_of(self, scores):
        bucket = np.searchsorted(self.taus, scores)          # 0..g
        return self.ks[bucket]

    def query(self, keys, keys_u64=None) -> np.ndarray:
        strs, keys = _norm_learned_keys(keys, keys_u64)
        ks = self._k_of(self.score_fn(strs))
        bits = self.bf.bits.test_bits(self.bf.key_bits(keys))
        mask = np.arange(self.bf.k)[None, :] < ks[:, None]
        return (bits | ~mask).all(axis=1)

    @property
    def size_bytes(self) -> float:
        return self.model_bytes + self.bf.size_bytes

    def summary(self) -> dict:
        return {"filter": "AdaBF",
                "model_kind": getattr(self.score_fn, "model_kind", "?"),
                "model_bytes": self.model_bytes,
                "groups": len(self.ks), "m_bits": self.bf.bits.m,
                "size_bytes": self.size_bytes}

    def to_artifact(self):
        from ..kernels.artifacts import AdaBFArtifact
        return AdaBFArtifact.from_arrays(
            params=self.score_fn.params, bf=self.bf.to_artifact(),
            taus=np.asarray(self.taus, np.float32),
            ks=np.asarray(self.ks, np.int32),
            model_kind=self.score_fn.model_kind)


def build_adabf(pos_strs, pos_u64, neg_strs, neg_u64, total_bytes,
                groups=4, k_max=8, model="mlp", seed=0) -> AdaBF:
    score_fn, mbytes = train_classifier(pos_strs, neg_strs, model=model,
                                        seed=seed)
    budget = max(64, total_bytes - mbytes)
    neg_scores = score_fn(neg_strs)
    qs = np.quantile(neg_scores, np.linspace(0.5, 0.98, groups - 1))
    # float32 so the host bucket lookup agrees bit-exactly with the device
    # artifact path (scores are float32 on both sides)
    taus = np.sort(np.unique(qs.astype(np.float32)))
    ks = np.linspace(k_max, 1, len(taus) + 1).round().astype(np.int64)
    bf = BloomFilter(max(64, budget * 8), k_max)
    pos_scores = score_fn(pos_strs)
    bucket = np.searchsorted(taus, pos_scores)
    kper = ks[bucket]
    bits = bf.key_bits(np.asarray(pos_u64, np.uint64))
    mask = np.arange(k_max)[None, :] < kper[:, None]
    bf.bits.set_bits(bits[mask])
    return AdaBF(score_fn=score_fn, taus=taus, ks=ks, bf=bf,
                 model_bytes=mbytes)
