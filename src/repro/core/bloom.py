"""Standard Bloom filter (paper baseline + HABF's underlying bit vector).

Bits are stored word-packed (uint32) so the same buffer is consumed by the
device-side query kernels.  Host construction / query are fully vectorized
numpy.  Per-key hash-function sets are supported (HABF's phi); the classic
filter is the special case where every key uses the same H0.
"""
from __future__ import annotations

import math

import numpy as np

from . import hashing
from .api import SpaceBudget


class BitVector:
    """Word-packed bit vector with vectorized set/test."""

    def __init__(self, m_bits: int):
        self.m = int(m_bits)
        self.words = np.zeros(((self.m + 31) // 32,), np.uint32)

    def set_bits(self, idx: np.ndarray) -> None:
        idx = np.asarray(idx).reshape(-1)
        np.bitwise_or.at(self.words, idx >> 5,
                         (np.uint32(1) << (idx & 31).astype(np.uint32)))

    def clear_bit(self, i: int) -> None:
        self.words[i >> 5] &= ~(np.uint32(1) << np.uint32(i & 31))

    def test_bits(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        return (self.words[idx >> 5] >> (idx & 31).astype(np.uint32)) & 1

    def count(self) -> int:
        return int(np.unpackbits(self.words.view(np.uint8)).sum())


def optimal_k(bits_per_key: float) -> int:
    return max(1, int(round(math.log(2) * bits_per_key)))


class BloomFilter:
    """Classic Bloom filter over 64-bit key fingerprints.

    hash_idx: the k global-family hash indices used by *all* keys (H0).
    """

    def __init__(self, m_bits: int, k: int, family=hashing.FAMILY,
                 hash_idx: np.ndarray | None = None):
        self.bits = BitVector(m_bits)
        self.k = int(k)
        self.family = family
        self.hash_idx = (np.arange(k, dtype=np.int64)
                         if hash_idx is None else np.asarray(hash_idx, np.int64))
        assert len(self.hash_idx) == self.k

    # -- unified construction ----------------------------------------------
    @classmethod
    def build(cls, pos_keys, neg_keys=None, costs=None, *,
              space: SpaceBudget | int, seed: int = 0,
              k: int | None = None) -> "BloomFilter":
        """Unified `Filter` build: size from the space budget, k optimal for
        the resulting bits/key unless given.  neg_keys/costs are accepted
        for signature uniformity and ignored (BF is cost-oblivious)."""
        if not isinstance(space, SpaceBudget):
            space = SpaceBudget(int(space))
        pos = hashing.as_u64_keys(pos_keys)
        if k is None:
            # cap at the global family size (tiny key sets would otherwise
            # ask for more hash functions than |H|)
            k = min(optimal_k(space.bits_per_key(len(pos))),
                    len(hashing.FAMILY["c1"]))
        bf = cls(space.total_bits, k)
        if len(pos):
            bf.insert(pos)
        return bf

    # -- vectorized index computation -------------------------------------
    def key_bits(self, keys_u64: np.ndarray,
                 phi: np.ndarray | None = None) -> np.ndarray:
        """(n, k) bit indices.  phi: optional (n, k) per-key hash indices."""
        keys_u64 = np.asarray(keys_u64, np.uint64)
        if phi is None:
            idx = hashing.hash_index_np(keys_u64[:, None], self.hash_idx[None, :],
                                        self.bits.m, self.family)
        else:
            idx = hashing.hash_index_np(keys_u64[:, None], np.asarray(phi),
                                        self.bits.m, self.family)
        return idx

    # -- operations --------------------------------------------------------
    def insert(self, keys, phi: np.ndarray | None = None) -> None:
        self.bits.set_bits(self.key_bits(hashing.as_u64_keys(keys), phi))

    def query(self, keys, phi: np.ndarray | None = None) -> np.ndarray:
        """Vectorized membership test -> bool (n,)."""
        idx = self.key_bits(hashing.as_u64_keys(keys), phi)
        return self.bits.test_bits(idx).all(axis=-1)

    # -- device export -------------------------------------------------------
    def to_artifact(self):
        """Typed pytree artifact for `repro.kernels.query` (per-H0-index
        constants pre-gathered; static shape/meta in aux_data)."""
        from ..kernels.artifacts import BloomArtifact
        idx = self.hash_idx
        return BloomArtifact.from_arrays(
            words=self.bits.words, c1=self.family["c1"][idx],
            c2=self.family["c2"][idx], mul=self.family["mul"][idx],
            m=self.bits.m, k=self.k, double_hash=False)

    @property
    def size_bytes(self) -> int:
        return self.bits.words.nbytes

    def summary(self) -> dict:
        return {"filter": type(self).__name__, "m_bits": self.bits.m,
                "k": self.k, "bits_set": self.bits.count(),
                "size_bytes": self.size_bytes}


class DoubleHashBloomFilter(BloomFilter):
    """f-HABF / Kirsch–Mitzenmacher double-hashing variant: g_i = h_a + i*h_b.
    `hash index` i is the multiplier, so phi rows are still integer index sets."""

    def key_bits(self, keys_u64, phi=None):
        keys_u64 = np.asarray(keys_u64, np.uint64)
        idx = self.hash_idx[None, :] if phi is None else np.asarray(phi)
        hv = hashing.double_hash_value_np(keys_u64[:, None], idx, self.family)
        return hashing.fastrange_np(hv, self.bits.m)

    def to_artifact(self):
        """Double hashing needs only the two base mixers; `double_hash=True`
        in the artifact's static meta makes the dispatch explicit (no
        class-name sniffing downstream)."""
        from ..kernels.artifacts import BloomArtifact
        return BloomArtifact.from_arrays(
            words=self.bits.words, c1=self.family["c1"][:2],
            c2=self.family["c2"][:2], mul=self.family["mul"][:2],
            m=self.bits.m, k=self.k, double_hash=True)
