"""Hash Adaptive Bloom Filter (paper §III) — public API.

HABF = standard Bloom filter + HashExpressor, built by TPJO, queried with
the two-round pattern:

  round 1: query BF with H0.  positive -> POSITIVE.
  round 2: walk HashExpressor for phi(e); if the walk is valid and the BF
           passes under phi(e) -> POSITIVE; else NEGATIVE.

Zero FNR: an unadjusted positive passes round 1 (its H0 bits are never
cleared — TPJO only clears bits solely mapped by the key being adjusted);
an adjusted positive is in the HashExpressor, retrieves its exact phi and
passes round 2.

Space layout (paper §V-D): given total bytes and allocation ratio
Delta = |HashExpressor| / |BF| (default 0.25 = paper's optimum), cell size
alpha = 1 + ceil(log2(n_hash + 1)) bits.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import hashing
from .api import SpaceBudget
from .tpjo import build_tpjo, TPJOResult


@dataclass
class HABFConfig:
    total_bytes: int = 2 * 1024 * 1024
    delta: float = 0.25          # HashExpressor : BF space ratio (paper: 1:4)
    k: int = 3                   # paper default (§V-D2)
    n_hash: int = hashing.DEFAULT_N_HASH
    seed: int = 0
    fast: bool = False           # f-HABF: double hashing + Gamma disabled

    @property
    def cell_bits(self) -> int:
        return 1 + int(np.ceil(np.log2(self.n_hash + 1)))

    def split(self) -> tuple[int, int]:
        """(m_bits for BF, omega cells for HashExpressor)."""
        total_bits = self.total_bytes * 8
        hx_bits = int(total_bits * self.delta / (1.0 + self.delta))
        omega = max(self.k + 1, hx_bits // self.cell_bits)
        m_bits = max(64, total_bits - omega * self.cell_bits)
        return m_bits, omega


class HABF:
    """Build with `HABF.build(...)`, query with `.query(keys)` (host) or
    export `.to_artifact()` for the jnp/Pallas query path."""

    def __init__(self, result: TPJOResult, config: HABFConfig):
        self.bf = result.bf
        self.hx = result.hx
        self.phi_pos = result.phi_pos
        self.adjusted = result.adjusted
        self.stats = result.stats
        self.config = config
        self.h0 = self.bf.hash_idx

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, pos_keys, neg_keys=None,
              neg_costs: np.ndarray | None = None,
              config: HABFConfig | None = None, *,
              space: SpaceBudget | int | None = None, **overrides) -> "HABF":
        """Build via TPJO.  `space=` (SpaceBudget or bytes) is the unified
        `Filter` spelling of total_bytes; neg_keys may be None (no observed
        negative stream — TPJO degenerates to a plain optimal BF + empty
        HashExpressor, still zero-FNR)."""
        if space is not None:
            if isinstance(space, SpaceBudget):
                space = space.total_bytes
            overrides.setdefault("total_bytes", int(space))
        config = config or HABFConfig(**overrides)
        pos = hashing.as_u64_keys(pos_keys)
        neg = (np.zeros((0,), np.uint64) if neg_keys is None
               else hashing.as_u64_keys(neg_keys))
        m_bits, omega = config.split()
        result = build_tpjo(pos, neg, neg_costs, m_bits, omega,
                            config.k, n_hash=config.n_hash, seed=config.seed,
                            fast=config.fast)
        return cls(result, config)

    # ------------------------------------------------------------------
    def query(self, keys) -> np.ndarray:
        """Two-round membership test, vectorized on host.  -> bool (n,)."""
        keys = hashing.as_u64_keys(keys)
        round1 = self.bf.query(keys)                       # H0
        phi, valid = self.hx.query(keys)
        round2 = self.bf.query(keys, phi=phi)
        return round1 | (valid & round2)

    # ------------------------------------------------------------------
    def to_artifact(self):
        """Typed pytree artifact for the fused two-round device query."""
        from ..kernels.artifacts import HABFArtifact
        return HABFArtifact.from_filter(self)

    @property
    def size_bytes(self) -> float:
        return self.bf.size_bytes + self.hx.size_bytes

    def summary(self) -> dict:
        d = self.stats.as_dict()
        d.update(m_bits=self.bf.bits.m, omega=self.hx.omega,
                 k=self.config.k, fast=self.config.fast,
                 bits_set=self.bf.bits.count(),
                 hx_inserted=self.hx.n_inserted)
        return d


def build_habf(pos_keys, neg_keys, neg_costs=None, **kw) -> HABF:
    return HABF.build(pos_keys, neg_keys, neg_costs, **kw)


def build_fhabf(pos_keys, neg_keys, neg_costs=None, **kw) -> HABF:
    kw.setdefault("fast", True)
    return HABF.build(pos_keys, neg_keys, neg_costs, **kw)
