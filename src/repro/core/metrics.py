"""Evaluation metrics (paper Eq. 1 / Eq. 20)."""
from __future__ import annotations

import numpy as np


def weighted_fpr(pred_pos: np.ndarray, costs: np.ndarray | None = None) -> float:
    """Weighted FPR over a *negative* key set: sum of costs of false
    positives / total cost.  With uniform costs this is the classic FPR."""
    pred_pos = np.asarray(pred_pos, bool)
    if costs is None:
        costs = np.ones(pred_pos.shape[0])
    costs = np.asarray(costs, np.float64)
    denom = costs.sum()
    return float((costs * pred_pos).sum() / denom) if denom else 0.0


def fpr(pred_pos: np.ndarray) -> float:
    return weighted_fpr(pred_pos, None)


def fnr(pred_pos_on_positives: np.ndarray) -> float:
    p = np.asarray(pred_pos_on_positives, bool)
    return float((~p).mean()) if len(p) else 0.0
