"""Weighted Bloom filter baseline (Bruck, Gao & Jiang 2006), paper §II.

Keys with higher query frequency / cost get more hash functions:
  k_e = clamp(round(k_bar + log2(theta(e) / geometric_mean(theta))), 1, k_max)

At query time WBF needs the key's cost to recover k_e; per the paper's
setup we cache the top-cost keys' k_e in a host-side dict and fall back to
k_bar for uncached keys (the cache is charged to construction memory).
"""
from __future__ import annotations

import numpy as np

from . import hashing
from .api import SpaceBudget
from .bloom import BloomFilter, optimal_k


def ks_for_costs(costs: np.ndarray, k_bar: int, k_max: int) -> np.ndarray:
    """Per-key hash counts from per-key costs (Bruck et al. Eq. above).
    Shared by the host filter and the device artifact path so the two can
    never diverge on the formula."""
    c = np.maximum(np.asarray(costs, np.float64), 1e-12)
    if c.size == 0:
        return np.zeros((0,), np.int64)
    geo = np.exp(np.mean(np.log(c)))
    k = np.round(k_bar + np.log2(c / geo)).astype(np.int64)
    return np.clip(k, 1, k_max)


class WeightedBloomFilter:
    def __init__(self, m_bits: int, k_bar: int, k_max: int = 8,
                 cache_fraction: float = 0.05):
        self.bf = BloomFilter(m_bits, k_max)          # holds k_max hash fns
        self.k_bar = int(max(1, k_bar))
        self.k_max = int(k_max)
        self.cache_fraction = float(cache_fraction)
        self.k_cache: dict[int, int] = {}
        # probe count for uncached keys: min(k_bar, min inserted k_e) — a
        # key inserted with k_e hashes sets bits 0..k_e-1, so probing any
        # prefix of that keeps the zero-FNR contract even for low-cost
        # keys that fell out of the cache (at some FPR cost)
        self.k_fallback = self.k_bar

    # -- unified construction -----------------------------------------------
    @classmethod
    def build(cls, pos_keys, neg_keys=None, costs=None, *,
              space: SpaceBudget | int, seed: int = 0,
              pos_costs: np.ndarray | None = None, k_bar: int | None = None,
              k_max: int = 8) -> "WeightedBloomFilter":
        """Unified `Filter` build.  WBF weights *insertions*: per-positive
        costs come in via `pos_costs` (the `costs` argument is the
        per-negative FP cost shared across the registry and is ignored
        here; neg_keys likewise)."""
        if not isinstance(space, SpaceBudget):
            space = SpaceBudget(int(space))
        pos = hashing.as_u64_keys(pos_keys)
        n_hash = len(hashing.FAMILY["c1"])
        if k_bar is None:
            k_bar = min(optimal_k(space.bits_per_key(len(pos))), n_hash)
        wbf = cls(space.total_bits, k_bar=k_bar,
                  k_max=min(max(k_max, k_bar), n_hash))
        wbf.insert(pos, pos_costs)
        return wbf

    def _k_for(self, costs: np.ndarray) -> np.ndarray:
        return ks_for_costs(costs, self.k_bar, self.k_max)

    def insert(self, pos_keys, pos_costs: np.ndarray | None = None) -> None:
        keys = hashing.as_u64_keys(pos_keys)
        costs = (np.ones(len(keys)) if pos_costs is None
                 else np.asarray(pos_costs, np.float64))
        ks = self._k_for(costs)
        bits = self.bf.key_bits(keys)                  # (n, k_max)
        mask = np.arange(self.k_max)[None, :] < ks[:, None]
        self.bf.bits.set_bits(bits[mask])
        if len(ks):
            self.k_fallback = min(self.k_fallback, int(ks.min()))
        # cache k for the most expensive keys (query-side retrieval)
        n_cache = int(len(keys) * self.cache_fraction)
        if n_cache:
            top = np.argsort(-costs, kind="stable")[:n_cache]
            self.k_cache = {int(keys[i]): int(ks[i]) for i in top}

    def query_ks(self, keys_u64: np.ndarray,
                 costs: np.ndarray | None = None) -> np.ndarray:
        """Per-key hash counts used at query time: from costs if given,
        else the top-cost cache with the zero-FNR fallback.  Shared by the
        host query and the device `query_keys` path so the two agree."""
        if costs is not None:
            return self._k_for(costs)
        return np.asarray([self.k_cache.get(int(x), self.k_fallback)
                           for x in keys_u64], np.int64)

    def query(self, keys, costs: np.ndarray | None = None) -> np.ndarray:
        keys = hashing.as_u64_keys(keys)
        ks = self.query_ks(keys, costs)
        bits_set = self.bf.bits.test_bits(self.bf.key_bits(keys))  # (n, k_max)
        mask = np.arange(self.k_max)[None, :] < ks[:, None]
        return (bits_set | ~mask).all(axis=1)

    @property
    def size_bytes(self) -> float:
        return self.bf.size_bytes

    def summary(self) -> dict:
        return {"filter": "WeightedBloomFilter", "m_bits": self.bf.bits.m,
                "k_bar": self.k_bar, "k_max": self.k_max,
                "k_fallback": self.k_fallback,
                "n_cached_ks": len(self.k_cache),
                "size_bytes": self.size_bytes}

    def to_artifact(self):
        """Pytree artifact: the k_max-probe table plus the k-cache as
        (sorted key halves, k) leaf arrays so the device wrapper can
        reproduce the host's cached-k lookup."""
        from ..kernels.artifacts import WBFArtifact
        fam, idx = self.bf.family, self.bf.hash_idx
        ck = np.sort(np.asarray(list(self.k_cache), np.uint64))
        cv = np.asarray([self.k_cache[int(x)] for x in ck], np.int32)
        lo, hi = hashing.split_u64(ck)
        return WBFArtifact.from_arrays(
            words=self.bf.bits.words, c1=fam["c1"][idx], c2=fam["c2"][idx],
            mul=fam["mul"][idx], cache_lo=lo, cache_hi=hi, cache_k=cv,
            m=self.bf.bits.m, k_bar=self.k_bar, k_max=self.k_max,
            k_fallback=self.k_fallback)
