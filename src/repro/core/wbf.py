"""Weighted Bloom filter baseline (Bruck, Gao & Jiang 2006), paper §II.

Keys with higher query frequency / cost get more hash functions:
  k_e = clamp(round(k_bar + log2(theta(e) / geometric_mean(theta))), 1, k_max)

At query time WBF needs the key's cost to recover k_e; per the paper's
setup we cache the top-cost keys' k_e in a host-side dict and fall back to
k_bar for uncached keys (the cache is charged to construction memory).
"""
from __future__ import annotations

import numpy as np

from .bloom import BloomFilter


class WeightedBloomFilter:
    def __init__(self, m_bits: int, k_bar: int, k_max: int = 8,
                 cache_fraction: float = 0.05):
        self.bf = BloomFilter(m_bits, k_max)          # holds k_max hash fns
        self.k_bar = int(max(1, k_bar))
        self.k_max = int(k_max)
        self.cache_fraction = float(cache_fraction)
        self.k_cache: dict[int, int] = {}

    def _k_for(self, costs: np.ndarray) -> np.ndarray:
        c = np.maximum(np.asarray(costs, np.float64), 1e-12)
        geo = np.exp(np.mean(np.log(c)))
        k = np.round(self.k_bar + np.log2(c / geo)).astype(np.int64)
        return np.clip(k, 1, self.k_max)

    def build(self, pos_keys: np.ndarray, pos_costs: np.ndarray | None) -> None:
        keys = np.asarray(pos_keys, np.uint64)
        costs = (np.ones(len(keys)) if pos_costs is None
                 else np.asarray(pos_costs, np.float64))
        ks = self._k_for(costs)
        bits = self.bf.key_bits(keys)                  # (n, k_max)
        mask = np.arange(self.k_max)[None, :] < ks[:, None]
        self.bf.bits.set_bits(bits[mask])
        # cache k for the most expensive keys (query-side retrieval)
        n_cache = int(len(keys) * self.cache_fraction)
        if n_cache:
            top = np.argsort(-costs, kind="stable")[:n_cache]
            self.k_cache = {int(keys[i]): int(ks[i]) for i in top}

    def query(self, keys_u64: np.ndarray,
              costs: np.ndarray | None = None) -> np.ndarray:
        keys = np.asarray(keys_u64, np.uint64).reshape(-1)
        if costs is not None:
            ks = self._k_for(costs)
        else:
            ks = np.asarray([self.k_cache.get(int(x), self.k_bar) for x in keys],
                            np.int64)
        bits_set = self.bf.bits.test_bits(self.bf.key_bits(keys))  # (n, k_max)
        mask = np.arange(self.k_max)[None, :] < ks[:, None]
        return (bits_set | ~mask).all(axis=1)

    @property
    def size_bytes(self) -> float:
        return self.bf.size_bytes
