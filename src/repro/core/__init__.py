"""HABF core — the paper's contribution + all compared baselines, behind
one membership contract (`Filter` protocol + string registry, see api.py)."""
from .api import (Filter, SpaceBudget, available_filters, make_filter,
                  register_filter)
from .habf import HABF, HABFConfig, build_habf, build_fhabf
from .bloom import BloomFilter, DoubleHashBloomFilter, optimal_k
from .hash_expressor import HashExpressor
from .xor_filter import XorFilter, xor_filter_for_space
from .wbf import WeightedBloomFilter
from .costs import zipf_costs
from .metrics import weighted_fpr, fpr, fnr
from . import hashing, theory, datasets

__all__ = [
    "Filter", "SpaceBudget", "available_filters", "make_filter",
    "register_filter",
    "HABF", "HABFConfig", "build_habf", "build_fhabf",
    "BloomFilter", "DoubleHashBloomFilter", "optimal_k",
    "HashExpressor", "XorFilter", "xor_filter_for_space",
    "WeightedBloomFilter", "zipf_costs", "weighted_fpr", "fpr", "fnr",
    "hashing", "theory", "datasets",
]
