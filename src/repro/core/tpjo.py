"""Two-Phase Joint Optimization (paper §III-D).

Phase-I adjusts the hash set phi(e_s) of a positive key that solely maps a
bit hit by an expensive collision (false-positive) negative key; Phase-II
atomically inserts the adjusted phi into the HashExpressor.  Runtime
indices:

  V     (m,)  — <singleflag, keyid, hashslot>: bits mapped by exactly one
               (positive key, hash) pair, and by whom/which slot.
  Gamma (m,)  — buckets of currently-negative "optimized keys" mapped to
               each bit; used by Algorithm 1 conflict detection to charge
               the cost of collateral collisions before flipping a bit.
  CQ          — collision keys (negative keys currently testing positive),
               processed in descending cost order; collateral collisions
               are appended to the tail (paper Fig. 6).

Construction is host-side (control-plane event, like LevelDB filter
builds); the result exports flat arrays for the device-side query kernels.

Fidelity notes (DESIGN.md §8):
  * conflict detection tests "all bits of e_opk outside bucket nu are set"
    directly on the bit vector — equivalent to Algorithm 1's
    V.keyid != NULL test, and also correct for the (rare) key that maps to
    nu twice, which Algorithm 1's count==k-1 misses.
  * a positive key already adjusted once (resident in HashExpressor) is
    not re-adjusted: its walk cells may be shared, so changing phi again
    could corrupt other walks.  The paper is silent on re-adjustment.
  * f-HABF (paper §III-G): double hashing + Gamma disabled (conflict
    detection skipped entirely; collateral collisions are not tracked).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field

import numpy as np

from . import hashing
from .bloom import BloomFilter, DoubleHashBloomFilter
from .hash_expressor import HashExpressor


@dataclass
class TPJOStats:
    n_pos: int = 0
    n_neg: int = 0
    n_collision_initial: int = 0
    n_collision_total: int = 0
    n_optimized: int = 0
    n_failed_insert: int = 0
    n_failed_adjust: int = 0
    n_skipped_cost: int = 0
    n_side_fixed: int = 0          # collision keys fixed by earlier adjustments
    n_adjusted_pos: int = 0

    def as_dict(self):
        return self.__dict__.copy()


@dataclass
class TPJOResult:
    bf: BloomFilter
    hx: HashExpressor
    phi_pos: np.ndarray            # (|S|, k) final hash sets of positives
    adjusted: np.ndarray           # (|S|,) bool — inserted into HashExpressor
    stats: TPJOStats = field(default_factory=TPJOStats)


def _bits_all_set(bf: BloomFilter, bits_row: np.ndarray) -> bool:
    return bool(bf.bits.test_bits(bits_row).all())


def build_tpjo(pos_keys: np.ndarray, neg_keys: np.ndarray,
               neg_costs: np.ndarray, m_bits: int, omega: int, k: int,
               n_hash: int = hashing.DEFAULT_N_HASH, seed: int = 0,
               fast: bool = False, family=hashing.FAMILY,
               max_rounds: int | None = None) -> TPJOResult:
    """Run TPJO and return the optimized Bloom filter + HashExpressor.

    fast=True builds f-HABF: double hashing + Gamma disabled.
    """
    rng = np.random.default_rng(seed)
    pos_keys = np.asarray(pos_keys, np.uint64)
    neg_keys = np.asarray(neg_keys, np.uint64)
    neg_costs = np.ones(len(neg_keys)) if neg_costs is None else np.asarray(neg_costs, np.float64)
    n_pos, n_neg = len(pos_keys), len(neg_keys)
    stats = TPJOStats(n_pos=n_pos, n_neg=n_neg)

    bf_cls = DoubleHashBloomFilter if fast else BloomFilter
    bf = bf_cls(m_bits, k, family=family)
    hx = HashExpressor(omega, k, n_hash=n_hash, family=family, double_hash=fast)
    m = bf.bits.m

    # ---- initial insertion with H0 -----------------------------------------
    phi_pos = np.tile(np.arange(k, dtype=np.int64), (n_pos, 1))
    pos_bits = bf.key_bits(pos_keys)                       # (n_pos, k)
    bf.bits.set_bits(pos_bits)
    adjusted = np.zeros((n_pos,), bool)

    # ---- V: single-mapper index (vectorized construction) ------------------
    flat = pos_bits.reshape(-1)
    counts = np.bincount(flat, minlength=m)
    v_keyid = np.full((m,), -1, np.int64)
    v_hashslot = np.full((m,), -1, np.int8)
    single_mask = counts == 1
    # positions of the unique (key, slot) pair for single-mapped bits
    order = np.argsort(flat, kind="stable")
    sorted_bits = flat[order]
    first_of_bit = np.searchsorted(sorted_bits, np.nonzero(single_mask)[0])
    src = order[first_of_bit]
    v_keyid[single_mask] = src // k
    v_hashslot[single_mask] = (src % k).astype(np.int8)
    v_single = (counts <= 1).astype(np.uint8)   # singleflag: mapped <= once
    # bits mapped >=1 times have keyid of first mapper only when count==1;
    # for count>1 keyid stays -1 but singleflag=0 distinguishes them.

    # ---- negative key bits (fixed H0 forever) -------------------------------
    neg_bits = bf.key_bits(neg_keys)                       # (n_neg, k)
    neg_fp = bf.bits.test_bits(neg_bits).all(axis=1)       # collision keys
    stats.n_collision_initial = int(neg_fp.sum())

    # ---- Gamma: buckets of currently-negative keys --------------------------
    track_gamma = not fast
    gamma: dict[int, set] = defaultdict(set)
    if track_gamma:
        for o in np.nonzero(~neg_fp)[0]:
            for b in neg_bits[o]:
                gamma[int(b)].add(int(o))

    def gamma_add(o: int):
        for b in neg_bits[o]:
            gamma[int(b)].add(int(o))

    def gamma_remove(o: int):
        for b in neg_bits[o]:
            gamma[int(b)].discard(int(o))

    def conflicts_if_set(w: int) -> list:
        """Algorithm 1: optimized keys that become FP if bit w flips to 1."""
        if not track_gamma:
            return []
        out = []
        for o in gamma.get(w, ()):  # keys with some bit at w
            row = neg_bits[o]
            others = row[row != w]
            if others.size == 0 or bf.bits.test_bits(others).all():
                out.append(o)
        return out

    # ---- CQ: descending cost; collateral collisions appended at tail --------
    ck_init = np.nonzero(neg_fp)[0]
    cq = list(ck_init[np.argsort(-neg_costs[ck_init], kind="stable")])
    stats.n_collision_total = len(cq)

    all_hash = np.arange(n_hash, dtype=np.int64)
    rounds = 0
    budget = max_rounds if max_rounds is not None else 50 * max(1, n_neg)

    while cq and rounds < budget:
        rounds += 1
        o = int(cq.pop(0))
        row = neg_bits[o]
        if not _bits_all_set(bf, row):
            stats.n_side_fixed += 1
            continue  # already fixed as a side effect
        # xi_ck: units mapped once by a single (not-yet-adjusted) positive key
        cand_units = [int(u) for u in row
                      if v_single[u] == 1 and v_keyid[u] >= 0
                      and not adjusted[v_keyid[u]]]
        fixed = False
        for u in cand_units:
            s = int(v_keyid[u])
            slot = int(v_hashslot[u])
            phi_s = phi_pos[s]
            h_u = int(phi_s[slot])
            hc = np.setdiff1d(all_hash, phi_s, assume_unique=False)
            if hc.size == 0:
                continue
            # candidate replacement bits for e_s under each h_c
            w_bits = bf.key_bits(np.asarray([pos_keys[s]]), phi=hc[None, :])[0]
            set_already = bf.bits.test_bits(w_bits).astype(bool)
            # rank candidates: (0) target bit already 1 — zero damage;
            # (1) clean bucket; (2) damaged bucket with min cost <= Theta(e_ck)
            zero_damage = [(int(h), int(w)) for h, w, sb in zip(hc, w_bits, set_already) if sb]
            clean, damaged = [], []
            for h, w, sb in zip(hc, w_bits, set_already):
                if sb:
                    continue
                if w == u:
                    continue  # replacing h_u with a hash mapping to the same bit is useless
                zeta = conflicts_if_set(int(w))
                if not zeta:
                    clean.append((int(h), int(w)))
                else:
                    cost_w = float(neg_costs[zeta].sum()) if zeta else 0.0
                    damaged.append((cost_w, int(h), int(w), zeta))
            # phase-II: try zero-damage + clean candidates.  HABF ranks all
            # insertable plans by overlap (fewest new writes); f-HABF takes
            # the first fit (§III-G: speed over selection quality).
            trials = []
            for h, w in zero_damage + clean:
                new_phi = phi_s.copy()
                new_phi[slot] = h
                ok, plan = hx.plan_insert(pos_keys[s], new_phi, rng)
                if ok:
                    trials.append((plan[2], h, w, None, plan))
                    if fast:
                        break
            chosen = min(trials, key=lambda t: (t[0], t[1])) if trials else None
            if chosen is None and damaged:
                damaged.sort(key=lambda t: (t[0], t[1]))
                for cost_w, h, w, zeta in damaged:
                    if cost_w > float(neg_costs[o]):
                        stats.n_skipped_cost += 1
                        break  # sorted: all further are worse
                    new_phi = phi_s.copy()
                    new_phi[slot] = h
                    ok, plan = hx.plan_insert(pos_keys[s], new_phi, rng)
                    if ok:
                        chosen = (plan[2], h, w, zeta, plan)
                        break
                    stats.n_failed_insert += 1
            if chosen is None:
                continue
            _, h_new, w, zeta, plan = chosen
            # ---- commit ------------------------------------------------------
            new_phi = phi_s.copy()
            new_phi[slot] = h_new
            hx.commit_plan(plan)
            phi_pos[s] = new_phi
            adjusted[s] = True
            stats.n_adjusted_pos += 1
            # Bloom filter: clear the solely-mapped bit u, set bit w
            bf.bits.clear_bit(u)
            bf.bits.set_bits(np.asarray([w]))
            # V updates: reset u; account e_s mapping at w
            v_single[u] = 1
            v_keyid[u] = -1
            v_hashslot[u] = -1
            if v_keyid[w] == -1 and v_single[w] == 1:
                # empty unit: e_s is now its only mapper... but only if the
                # bit was previously unmapped by positives (count==0)
                v_keyid[w] = s
                v_hashslot[w] = np.int8(slot)
            elif v_single[w] == 1:
                v_single[w] = 0
            # collateral collisions -> tail of CQ; e_ck becomes optimized
            if zeta:
                for oc in zeta:
                    gamma_remove(oc)
                    cq.append(oc)
                    stats.n_collision_total += 1
            if track_gamma:
                gamma_add(o)
            stats.n_optimized += 1
            fixed = True
            break
        if not fixed:
            stats.n_failed_adjust += 1

    return TPJOResult(bf=bf, hx=hx, phi_pos=phi_pos, adjusted=adjusted,
                      stats=stats)
