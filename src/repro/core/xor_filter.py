"""Xor filter baseline (Graf & Lemire 2020), paper §V-A.

Static filter: each key is mapped to 3 slots (one per table third); the
b-bit fingerprint of a key equals the xor of its 3 slots.  Construction
uses the standard hypergraph peeling; capacity 1.23|S| + 32 per the paper
(fingerprint bits = floor(b / (1.23 + 32/|S|)) for bits-per-key b).
"""
from __future__ import annotations

import numpy as np

from . import hashing
from .api import SpaceBudget

_FP_FAMILY = hashing.make_family(4, seed=0x0F0F)
_SALT_STEP = 0x9E3779B97F4A7C15


def _slots(keys: np.ndarray, seg_len: int, seed_round: int) -> np.ndarray:
    """(n, 3) slot indices, one per segment third."""
    out = np.empty((len(keys), 3), np.int64)
    for j in range(3):
        hv = hashing.hash_value_np(keys ^ np.uint64(seed_round * _SALT_STEP
                                                    & 0xFFFFFFFFFFFFFFFF),
                                   j, _FP_FAMILY)
        out[:, j] = hashing.fastrange_np(hv, seg_len) + j * seg_len
    return out


def _fingerprint(keys: np.ndarray, bits: int) -> np.ndarray:
    hv = hashing.hash_value_np(keys, 3, _FP_FAMILY).astype(np.uint32)
    fp = hv & np.uint32((1 << bits) - 1)
    return np.maximum(fp, 1).astype(np.uint32)  # avoid 0 fingerprints


class XorFilter:
    def __init__(self, keys_u64, fingerprint_bits: int = 8,
                 max_rounds: int = 64):
        keys = np.unique(hashing.as_u64_keys(keys_u64))
        self.fp_bits = int(max(1, min(fingerprint_bits, 32)))
        n = max(1, len(keys))
        seg = int(np.ceil(1.23 * n / 3)) + 11
        self.seg_len = seg
        self.table = np.zeros((3 * seg,), np.uint32)
        self.seed_round = self._peel_and_assign(keys, max_rounds)

    # -- construction: peeling ------------------------------------------------
    def _peel_and_assign(self, keys: np.ndarray, max_rounds: int) -> int:
        n = len(keys)
        for rnd in range(max_rounds):
            slots = _slots(keys, self.seg_len, rnd)
            deg = np.bincount(slots.reshape(-1), minlength=3 * self.seg_len)
            # peel: repeatedly remove keys that own a degree-1 slot
            slot_owner = np.full((3 * self.seg_len,), -1, np.int64)
            # build inverted index lazily via sorting
            stack: list[tuple[int, int]] = []  # (key_idx, slot)
            alive = np.ones((n,), bool)
            # queue of degree-1 slots
            from collections import deque
            flat = slots.reshape(-1)
            order = np.argsort(flat, kind="stable")
            starts = np.searchsorted(flat[order], np.arange(3 * self.seg_len))
            ends = np.searchsorted(flat[order], np.arange(3 * self.seg_len) + 1)

            def keys_at(slot):
                return order[starts[slot]:ends[slot]] // 3

            q = deque(np.nonzero(deg == 1)[0].tolist())
            deg = deg.copy()
            while q:
                slot = q.popleft()
                if deg[slot] != 1:
                    continue
                cand = [ki for ki in keys_at(slot) if alive[ki]]
                if not cand:
                    continue
                ki = cand[0]
                stack.append((ki, slot))
                alive[ki] = False
                for s2 in slots[ki]:
                    deg[s2] -= 1
                    if deg[s2] == 1:
                        q.append(int(s2))
            if alive.any():
                continue  # peeling failed; retry with fresh hash seeds
            # assign in reverse peel order
            self.table[:] = 0
            fps = _fingerprint(keys, self.fp_bits)
            for ki, slot in reversed(stack):
                s0, s1, s2 = slots[ki]
                want = fps[ki]
                self.table[slot] = want ^ self.table[s0] ^ self.table[s1] ^ self.table[s2] ^ self.table[slot]
            self._slots_cache_round = rnd
            return rnd
        raise RuntimeError("xor filter peeling failed after max_rounds")

    # -- unified construction -----------------------------------------------
    @classmethod
    def build(cls, pos_keys, neg_keys=None, costs=None, *,
              space: SpaceBudget | int, seed: int = 0,
              fingerprint_bits: int | None = None) -> "XorFilter":
        """Unified `Filter` build (static structure: neg/costs/seed are
        accepted for signature uniformity and ignored).  Fingerprint bits
        default to the paper's space-fill formula (§V-A)."""
        if not isinstance(space, SpaceBudget):
            space = SpaceBudget(int(space))
        if fingerprint_bits is not None:
            return cls(pos_keys, fingerprint_bits=fingerprint_bits)
        return xor_filter_for_space(hashing.as_u64_keys(pos_keys),
                                    space.total_bytes)

    # -- query ------------------------------------------------------------------
    def query(self, keys) -> np.ndarray:
        keys = hashing.as_u64_keys(keys)
        slots = _slots(keys, self.seg_len, self.seed_round)
        fp = _fingerprint(keys, self.fp_bits)
        got = (self.table[slots[:, 0]] ^ self.table[slots[:, 1]]
               ^ self.table[slots[:, 2]])
        return got == fp

    @property
    def size_bytes(self) -> float:
        return self.table.shape[0] * self.fp_bits / 8.0

    def summary(self) -> dict:
        return {"filter": "XorFilter", "fp_bits": self.fp_bits,
                "seg_len": self.seg_len, "seed_round": self.seed_round,
                "size_bytes": self.size_bytes}

    def to_artifact(self):
        from ..kernels.artifacts import XorArtifact
        return XorArtifact.from_arrays(
            table=self.table, c1=_FP_FAMILY["c1"], c2=_FP_FAMILY["c2"],
            mul=_FP_FAMILY["mul"], seg_len=self.seg_len, fp_bits=self.fp_bits,
            seed_round=self.seed_round)


def xor_filter_for_space(keys_u64: np.ndarray, total_bytes: int) -> XorFilter:
    """Pick fingerprint bits to fill the given space (paper §V-A formula)."""
    n = max(1, len(np.unique(np.asarray(keys_u64, np.uint64))))
    bpk = total_bytes * 8.0 / n
    bits = int(bpk / (1.23 + 32.0 / n))
    bits = max(2, min(bits, 32))
    return XorFilter(keys_u64, fingerprint_bits=bits)
