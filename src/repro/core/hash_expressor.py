"""HashExpressor (paper §III-C): an omega-cell probabilistic hash table
storing customized hash-function subsets as a k-step pointer walk.

Cell = <endbit, hashindex>.  hashindex is stored 1-based (0 == empty) so a
cell of alpha bits represents up to 2^(alpha-1) - 1 hash functions,
matching the paper's cell-size analysis (§V-D3).

Insertion walks the table resolving one hash of phi per step (Case 1:
claim an empty cell with a random unresolved hash; Case 2: share a cell
that already stores an unresolved hash; Case 3: fail).  The endbit of the
last visited cell is set.  Insertions never overwrite non-empty cells, so
earlier keys' walks remain intact — the zero-FNR invariant (tested).

Query replays the walk: cell_1 = f(e); cell_{i+1} = h_{cell_i}(e); valid
iff all cells non-empty and the k-th cell's endbit is 1.
"""
from __future__ import annotations

import numpy as np

from . import hashing

# Dedicated constants for the predefined "unified" hash function f.
F_FAMILY = hashing.make_family(1, seed=0xF00D)


class HashExpressor:
    def __init__(self, omega: int, k: int, n_hash: int = hashing.DEFAULT_N_HASH,
                 family=hashing.FAMILY, double_hash: bool = False):
        self.omega = int(omega)
        self.k = int(k)
        self.n_hash = int(n_hash)
        self.family = family
        self.double_hash = bool(double_hash)
        self.endbit = np.zeros((self.omega,), np.uint8)
        self.hashidx = np.zeros((self.omega,), np.uint8)  # 0 = empty
        self.n_inserted = 0

    # -- hashing helpers ----------------------------------------------------
    def _hv(self, keys_u64, hash_idx):
        if self.double_hash:
            return hashing.double_hash_value_np(keys_u64, hash_idx, self.family)
        return hashing.hash_value_np(keys_u64, hash_idx, self.family)

    def _cell_f(self, keys_u64):
        hv = hashing.hash_value_np(keys_u64, 0, F_FAMILY)
        return hashing.fastrange_np(hv, self.omega)

    def _cell_h(self, keys_u64, hash_idx):
        return hashing.fastrange_np(self._hv(keys_u64, hash_idx), self.omega)

    # -- insertion (host, per-key; construction-time only) -------------------
    def plan_insert(self, key_u64, phi, rng: np.random.Generator):
        """Walk the table for hash set `phi` (0-based indices) without
        mutating it.  Returns (ok, plan) where plan = (writes dict
        {cell: 1-based hashindex}, last_cell, n_writes).  The plan can be
        applied later with `commit_plan` — phase-II tests tentatively and
        commits the cheapest viable plan (max overlap = fewest writes)."""
        key = np.uint64(key_u64)
        invalid = list(dict.fromkeys(int(h) for h in phi))  # order-stable uniq
        if len(invalid) != self.k:
            return False, None
        pending: dict[int, int] = {}  # cell -> 1-based hashindex to write
        cur_idx = None  # None => use f
        last_cell = -1
        for _ in range(self.k):
            cell = int(self._cell_f(key) if cur_idx is None
                       else self._cell_h(key, cur_idx))
            content = pending.get(cell, int(self.hashidx[cell]))
            if content == 0:
                h = int(invalid[int(rng.integers(len(invalid)))])
                pending[cell] = h + 1
                invalid.remove(h)
                cur_idx = h
            elif (content - 1) in invalid:
                h = content - 1
                invalid.remove(h)
                cur_idx = h
            else:
                return False, None
            last_cell = cell
        n_writes = len(pending) + (0 if self.endbit[last_cell] else 1)
        return True, (pending, last_cell, n_writes)

    def commit_plan(self, plan) -> None:
        pending, last_cell, _ = plan
        for cell, hidx in pending.items():
            self.hashidx[cell] = np.uint8(hidx)
        self.endbit[last_cell] = 1
        self.n_inserted += 1

    def try_insert(self, key_u64, phi, rng: np.random.Generator,
                   commit: bool = True):
        """Back-compat wrapper: returns (ok, n_new_cell_writes)."""
        ok, plan = self.plan_insert(key_u64, phi, rng)
        if not ok:
            return False, 0
        if commit:
            self.commit_plan(plan)
        return True, plan[2]

    # -- query (host, vectorized over keys) ----------------------------------
    def query(self, keys_u64: np.ndarray):
        """Returns (phi (n, k) int64 0-based hash indices, valid (n,) bool).
        Invalid rows should be treated as phi = H0 by the caller."""
        keys = np.asarray(keys_u64, np.uint64).reshape(-1)
        n = keys.shape[0]
        phi = np.zeros((n, self.k), np.int64)
        valid = np.ones((n,), bool)
        cell = self._cell_f(keys)
        last_end = np.zeros((n,), np.uint8)
        for step in range(self.k):
            content = self.hashidx[cell].astype(np.int64)
            valid &= content != 0
            hidx = np.maximum(content - 1, 0)
            phi[:, step] = hidx
            last_end = self.endbit[cell]
            if step + 1 < self.k:
                cell = self._cell_h(keys, hidx)
        valid &= last_end == 1
        # a customized phi must differ from H0 as a *set*; duplicate-hash rows
        # are structurally impossible for inserted keys, keep as-is.
        return phi, valid

    @property
    def size_bytes(self) -> float:
        # alpha = 1 endbit + ceil(log2(n_hash+1)) hashindex bits per cell
        alpha = 1 + int(np.ceil(np.log2(self.n_hash + 1)))
        return self.omega * alpha / 8.0
