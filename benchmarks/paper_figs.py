"""One benchmark per paper table/figure (§V), CSV rows
(name, us_per_call, derived).  Dataset sizes scale with --scale; the
defaults keep the whole suite CPU-friendly while preserving every
qualitative claim (HABF < f-HABF < baselines on weighted FPR, etc.)."""
from __future__ import annotations

import time
import tracemalloc

import numpy as np

from repro.core import (HABF, BloomFilter, DoubleHashBloomFilter,
                        SpaceBudget, make_filter, optimal_k, weighted_fpr,
                        zipf_costs, theory)
from repro.core.datasets import make_dataset
from repro.core import hashing


def _bits_total(n_pos: int, bpk: float) -> int:
    return int(n_pos * bpk / 8)


def _time_per_key(fn, n_keys: int, repeat: int = 1) -> float:
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat / max(1, n_keys) * 1e9  # ns


# ---------------------------------------------------------------------------
# Fig 8 — theoretical bound of F*_bf vs measured
# ---------------------------------------------------------------------------

def fig8_theory_bound(scale=0.01, seed=0):
    rows = []
    ds = make_dataset("shalla", scale, seed)
    for k in (2, 4, 6, 8, 10):
        h = HABF.build(ds.pos_u64, ds.neg_u64, None,
                       total_bytes=_bits_total(ds.n_pos, 10), k=k, seed=seed)
        s = h.summary()
        measured = h.bf.query(ds.neg_u64).mean()
        fbf = s["n_collision_total"] / s["n_neg"]
        p_c = theory.p_xi_lower(10, k)
        bound = theory.fbf_star_upper(fbf, s["n_collision_initial"], p_c, k,
                                      s["omega"], s["n_neg"])
        rows.append((f"fig8_k{k}", 0.0,
                     f"measured={measured:.2e};bound={bound:.2e};"
                     f"holds={measured <= bound + 1e-12}"))
    for b in (4, 7, 10, 13):
        h = HABF.build(ds.pos_u64, ds.neg_u64, None,
                       total_bytes=_bits_total(ds.n_pos, b), k=4, seed=seed)
        s = h.summary()
        measured = h.bf.query(ds.neg_u64).mean()
        fbf = s["n_collision_total"] / s["n_neg"]
        bound = theory.fbf_star_upper(fbf, s["n_collision_initial"],
                                      theory.p_xi_lower(b, 4), 4,
                                      s["omega"], s["n_neg"])
        rows.append((f"fig8_b{b}", 0.0,
                     f"measured={measured:.2e};bound={bound:.2e};"
                     f"holds={measured <= bound + 1e-12}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 9 — parameters: Delta ratio, k, cell size
# ---------------------------------------------------------------------------

def fig9_parameters(scale=0.01, seed=0):
    rows = []
    ds = make_dataset("shalla", scale, seed)
    total = _bits_total(ds.n_pos, 10)
    for delta in (0.05, 0.15, 0.25, 0.4, 0.6):
        h = HABF.build(ds.pos_u64, ds.neg_u64, None, total_bytes=total,
                       delta=delta, k=3, seed=seed)
        rows.append((f"fig9_delta{delta}", 0.0,
                     f"wfpr={h.query(ds.neg_u64).mean():.3e}"))
    for k in (2, 3, 4, 5, 6, 8):
        h = HABF.build(ds.pos_u64, ds.neg_u64, None, total_bytes=total,
                       k=k, seed=seed)
        rows.append((f"fig9_k{k}", 0.0,
                     f"wfpr={h.query(ds.neg_u64).mean():.3e}"))
    for n_hash, cell in ((3, 3), (7, 4), (15, 5), (22, 6)):
        h = HABF.build(ds.pos_u64, ds.neg_u64, None, total_bytes=total,
                       k=3, n_hash=n_hash, seed=seed)
        rows.append((f"fig9_cell{cell}_nhash{n_hash}", 0.0,
                     f"wfpr={h.query(ds.neg_u64).mean():.3e}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 10/11 — weighted FPR vs space (uniform / Zipf 1.0), both datasets
# ---------------------------------------------------------------------------

_LEARNED = ("lbf", "slbf", "adabf")


def _filters_at(ds, total, costs, seed, with_learned=False):
    """One registry loop instead of per-filter construction blocks."""
    space = SpaceBudget(total)
    names = ["habf", "fhabf", "bloom", "xor", "wbf"]
    if with_learned:
        names += list(_LEARNED)
    out = {}
    for name in names:
        pos = ds.pos_strs if name in _LEARNED else ds.pos_u64
        neg = ds.neg_strs if name in _LEARNED else ds.neg_u64
        kw = {"k": 3} if name in ("habf", "fhabf") else {}
        out[name] = make_filter(name, pos, neg, costs, space=space,
                                seed=seed, **kw)
    return out


def _query_all(f, name, ds):
    return f.query(ds.neg_strs if name in _LEARNED else ds.neg_u64)


def fig10_11_fpr_vs_space(scale=0.01, seed=0, skew=0.0, dataset="shalla",
                          with_learned=True, tag="fig10"):
    rows = []
    ds = make_dataset(dataset, scale if dataset == "shalla" else scale / 5,
                      seed)
    costs = zipf_costs(ds.n_neg, skew, seed + 1)
    for bpk in (8, 10, 12, 14, 17):
        total = _bits_total(ds.n_pos, bpk)
        filters = _filters_at(ds, total, costs, seed,
                              with_learned=(with_learned and bpk in (10, 14)))
        for name, f in filters.items():
            w = weighted_fpr(_query_all(f, name, ds), costs)
            rows.append((f"{tag}_{dataset}_bpk{bpk}_{name}", 0.0,
                         f"wfpr={w:.3e}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 12 — construction + query time (ns/key)
# ---------------------------------------------------------------------------

def fig12_time(scale=0.01, seed=0):
    rows = []
    ds = make_dataset("shalla", scale, seed)
    total = _bits_total(ds.n_pos, 10)
    costs = zipf_costs(ds.n_neg, 1.0, seed)

    space = SpaceBudget(total)
    t0 = time.perf_counter()
    h = make_filter("habf", ds.pos_u64, ds.neg_u64, costs, space=space,
                    k=3, seed=seed)
    habf_c = (time.perf_counter() - t0) / (ds.n_pos + ds.n_neg) * 1e9
    t0 = time.perf_counter()
    hf = make_filter("fhabf", ds.pos_u64, ds.neg_u64, costs, space=space,
                     k=3, seed=seed)
    fhabf_c = (time.perf_counter() - t0) / (ds.n_pos + ds.n_neg) * 1e9
    t0 = time.perf_counter()
    bf = make_filter("bloom", ds.pos_u64, space=space)
    bf_c = (time.perf_counter() - t0) / ds.n_pos * 1e9
    t0 = time.perf_counter()
    xf = make_filter("xor", ds.pos_u64, space=space)
    xor_c = (time.perf_counter() - t0) / ds.n_pos * 1e9
    t0 = time.perf_counter()
    wbf = make_filter("wbf", ds.pos_u64, space=space)
    wbf_c = (time.perf_counter() - t0) / ds.n_pos * 1e9

    qn = len(ds.neg_u64)
    habf_q = _time_per_key(lambda: h.query(ds.neg_u64), qn, 3)
    fhabf_q = _time_per_key(lambda: hf.query(ds.neg_u64), qn, 3)
    bf_q = _time_per_key(lambda: bf.query(ds.neg_u64), qn, 3)
    xor_q = _time_per_key(lambda: xf.query(ds.neg_u64), qn, 3)
    wbf_q = _time_per_key(lambda: wbf.query(ds.neg_u64), qn, 3)
    for nm, c, q in (("habf", habf_c, habf_q), ("fhabf", fhabf_c, fhabf_q),
                     ("bf", bf_c, bf_q), ("xor", xor_c, xor_q),
                     ("wbf", wbf_c, wbf_q)):
        rows.append((f"fig12_construct_{nm}", c / 1e3, f"ns_per_key={c:.0f}"))
        rows.append((f"fig12_query_{nm}", q / 1e3, f"ns_per_key={q:.0f}"))
    # learned filter (paper: construction/query orders of magnitude slower)
    from repro.core.learned import build_lbf
    t0 = time.perf_counter()
    lbf = build_lbf(ds.pos_strs, ds.pos_u64, ds.neg_strs, ds.neg_u64, total)
    lbf_c = (time.perf_counter() - t0) / (ds.n_pos + ds.n_neg) * 1e9
    # two-arg form: keep fingerprinting out of the timed region (paper
    # methodology times the query, and the other filters use precomputed u64)
    lbf_q = _time_per_key(lambda: lbf.query(ds.neg_strs, ds.neg_u64), qn, 1)
    rows.append(("fig12_construct_lbf", lbf_c / 1e3, f"ns_per_key={lbf_c:.0f}"))
    rows.append(("fig12_query_lbf", lbf_q / 1e3, f"ns_per_key={lbf_q:.0f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 13 — weighted FPR vs skewness
# ---------------------------------------------------------------------------

def fig13_skew(scale=0.01, seed=0):
    rows = []
    ds = make_dataset("shalla", scale, seed)
    total = _bits_total(ds.n_pos, 10)
    for skew in (0.0, 0.6, 0.9, 1.2, 1.8, 2.4, 3.0):
        costs = zipf_costs(ds.n_neg, skew, seed + int(skew * 10))
        space = SpaceBudget(total)
        for nm in ("habf", "fhabf", "bloom", "xor"):
            kw = {"k": 3} if nm in ("habf", "fhabf") else {}
            f = make_filter(nm, ds.pos_u64, ds.neg_u64, costs, space=space,
                            seed=seed, **kw)
            rows.append((f"fig13_skew{skew}_{nm}", 0.0,
                         f"wfpr={weighted_fpr(f.query(ds.neg_u64), costs):.3e}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 14 — BF with different hash implementations
# ---------------------------------------------------------------------------

def fig14_hash_impls(scale=0.002, seed=0):
    rows = []
    ds = make_dataset("ycsb", scale, seed)
    total = _bits_total(ds.n_pos, 10)
    k = optimal_k(10)
    for skew in (0.0, 1.0):
        costs = zipf_costs(ds.n_neg, skew, seed + 5)
        variants = {
            "bf_family": BloomFilter(total * 8, k),
            "bf_seeded": BloomFilter(total * 8, k,
                                     family=hashing.make_family(k, seed=0xC17)),
            "bf_double": DoubleHashBloomFilter(total * 8, k),
        }
        for nm, bf in variants.items():
            bf.insert(ds.pos_u64)
            rows.append((f"fig14_{nm}_skew{skew}", 0.0,
                         f"wfpr={weighted_fpr(bf.query(ds.neg_u64), costs):.3e}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 15 — construction memory footprint
# ---------------------------------------------------------------------------

def fig15_memory(scale=0.005, seed=0):
    rows = []
    ds = make_dataset("shalla", scale, seed)
    total = _bits_total(ds.n_pos, 10)

    def peak(fn):
        tracemalloc.start()
        fn()
        _, pk = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        return pk

    builds = {
        nm: (lambda nm=nm: make_filter(
            nm, ds.pos_u64, ds.neg_u64, None, space=SpaceBudget(total),
            seed=seed, **({"k": 3} if nm in ("habf", "fhabf") else {})))
        for nm in ("habf", "fhabf", "bloom", "xor", "wbf")
    }
    for nm, fn in builds.items():
        rows.append((f"fig15_mem_{nm}", 0.0,
                     f"peak_mb={peak(fn) / 1e6:.1f}"))
    return rows
