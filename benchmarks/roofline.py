"""Roofline analysis over the dry-run records (EXPERIMENTS.md §Roofline).

Terms (per assignment, v5e constants):
  compute    = HLO_FLOPs_per_chip / 197e12  [s]
  memory     = HLO_bytes_per_chip / 819e9   [s]
  collective = collective_bytes_per_chip / 50e9  [s]

HLO_FLOPs/bytes come from the trip-count-scaled HLO analyzer (XLA's own
cost_analysis counts while bodies once — see launch/hlo_analysis.py).
MODEL_FLOPS = 6·N·D for train (N = active params, D = tokens), 2·N·D for
prefill, 2·N·B for a decode step; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/dispatch overhead.

  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,        # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: dict) -> float:
    n = rec["params_active"]
    toks = SHAPE_TOKENS[rec["shape"]]
    if rec["kind"] == "train":
        return 6.0 * n * toks
    return 2.0 * n * toks


def load_records(d: Path) -> list:
    recs = []
    for p in sorted(d.glob("*.json")):
        if "FAILED" in p.name:
            continue
        recs.append(json.loads(p.read_text()))
    return recs


def analyze_record(rec: dict) -> dict:
    chips = rec["n_devices"]
    flops_dev = rec.get("hlo_flops_per_device", 0.0)
    bytes_dev = rec.get("hlo_bytes_per_device", 0.0)
    coll_dev = sum(d["bytes"] for d in rec.get("collectives", {}).values())
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_l = coll_dev / LINK_BW
    mf = model_flops(rec)
    useful = mf / (flops_dev * chips) if flops_dev else 0.0
    terms = {"compute": t_c, "memory": t_m, "collective": t_l}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful-model-time / bound-time (how much of the
    # limiting resource feeds model math)
    t_model = mf / chips / PEAK_FLOPS
    frac = t_model / bound if bound else 0.0
    return {
        **{k: rec.get(k) for k in ("arch", "shape", "mesh", "kind", "accum")},
        "chips": chips,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_l,
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": flops_dev * chips,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "collective_detail": rec.get("collectives", {}),
        "temp_bytes": rec.get("temp_size_in_bytes"),
        "arg_bytes": rec.get("args_bytes_per_device"),
    }


_SUGGEST = {
    "compute": ("drop remat recompute / shrink dispatch-mask matmuls so "
                "HLO FLOPs approach 6ND"),
    "memory": ("raise arithmetic intensity: larger microbatch per chip, "
               "fuse norms/rope, keep KV cache reads coalesced"),
    "collective": ("reshard to cut the biggest collective (move all-gather "
                   "off the hot loop, overlap with compute, or compress)"),
}


def to_markdown(rows: list) -> str:
    hdr = ("| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | MODEL/HLO | roofline frac | next lever |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {_SUGGEST[r['dominant']][:52]}… |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()

    recs = load_records(Path(args.dir) / args.mesh)
    rows = [analyze_record(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    md = to_markdown(rows)
    Path(args.out).write_text(md + "\n")
    Path(args.json_out).write_text(json.dumps(rows, indent=1))
    print(md)
    print(f"\nwrote {args.out} and {args.json_out} ({len(rows)} cells)")
    # worst cells by roofline fraction (hillclimb candidates)
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("\nworst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']} x {r['shape']}: {r['roofline_fraction']:.3f} "
              f"({r['dominant']}-bound)")
    coll = sorted(rows, key=lambda r: -r["t_collective_s"])[:5]
    print("most collective-bound:")
    for r in coll:
        print(f"  {r['arch']} x {r['shape']}: t_coll={r['t_collective_s']:.3e}s"
              f" ({r['dominant']}-bound)")


if __name__ == "__main__":
    main()
