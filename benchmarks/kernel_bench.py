"""Device-kernel + serving throughput benchmarks (CSV rows)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import HABF, BloomFilter
from repro.core.datasets import make_dataset


def kernel_throughput(scale=0.01, seed=0, n_query=200_000):
    """Pallas (interpret) vs pure-jnp ref vs host numpy, keys/s.

    NOTE: on this CPU container the Pallas kernel runs in interpret mode —
    the number demonstrates correctness plumbing, not TPU performance; the
    jnp ref path is the portable production fallback."""
    import jax
    from repro.core import zipf_costs
    from repro.core.wbf import WeightedBloomFilter
    from repro.core.xor_filter import xor_filter_for_space
    from repro.kernels import query
    from repro.core.hashing import split_u64
    import jax.numpy as jnp

    rows = []
    ds = make_dataset("shalla", scale, seed)
    space_bytes = ds.n_pos * 10 // 8
    h = HABF.build(ds.pos_u64, ds.neg_u64, None,
                   total_bytes=space_bytes, k=3, seed=seed)
    xf = xor_filter_for_space(ds.pos_u64, space_bytes)
    wbf = WeightedBloomFilter(space_bytes * 8, k_bar=4)
    wbf.insert(ds.pos_u64, zipf_costs(ds.n_pos, 1.0, seed))
    rng = np.random.default_rng(seed)
    q = rng.choice(np.concatenate([ds.pos_u64, ds.neg_u64]), n_query)
    lo, hi = split_u64(q)
    lo, hi = jnp.asarray(lo), jnp.asarray(hi)
    habf_art = h.to_artifact()
    bloom_art = h.bf.to_artifact()
    xor_art = xf.to_artifact()
    wbf_art = wbf.to_artifact()
    # skewed per-key probe counts: the variable-k path, not the uniform one
    ks = jnp.asarray(wbf.query_ks(q), jnp.int32)

    def bench(fn, name):
        fn()  # compile/warm
        t0 = time.perf_counter()
        jax.block_until_ready(fn()) if name != "host" else fn()
        dt = time.perf_counter() - t0
        rows.append((f"kernel_{name}", dt / n_query * 1e6,
                     f"keys_per_s={n_query / dt:.3g}"))

    bench(lambda: h.query(q), "host")
    bench(lambda: query(habf_art, lo, hi, use_kernel=False), "habf_jnp_ref")
    bench(lambda: query(habf_art, lo, hi, use_kernel=True),
          "habf_pallas_interp")
    bench(lambda: query(bloom_art, lo, hi, use_kernel=False),
          "bloom_jnp_ref")
    bench(lambda: query(bloom_art, lo, hi, use_kernel=True),
          "bloom_pallas_interp")
    bench(lambda: query(xor_art, lo, hi, use_kernel=False), "xor_jnp_ref")
    bench(lambda: query(xor_art, lo, hi, use_kernel=True),
          "xor_pallas_interp")
    bench(lambda: query(wbf_art, lo, hi, ks=ks, use_kernel=False),
          "wbf_jnp_ref")
    bench(lambda: query(wbf_art, lo, hi, ks=ks, use_kernel=True),
          "wbf_pallas_interp")
    return rows


def serving_throughput(seed=0):
    from repro.launch.serve import run
    out = run(arch="qwen3-0.6b", reduced=True, batch=8, prompt_len=48,
              gen=16, seed=seed)
    fs = out["filter_stats"]
    adm = out["bank_telemetry"]["admission"]
    return [
        ("serve_tokens_per_s", 1e6 / max(out["tokens_per_s"], 1e-9),
         f"tokens_per_s={out['tokens_per_s']:.1f}"),
        ("serve_admission", 0.0,
         f"admitted={out['admitted']}/{out['batch']}"),
        ("serve_filter_habf_vs_bf", 0.0,
         f"habf_wfpr={fs['habf_weighted_fpr']:.2e};"
         f"bf_wfpr={fs['bf_weighted_fpr']:.2e}"),
        ("serve_bank_admission", 0.0,
         f"fused={adm['fused_queries']};hit_rate={adm['hit_rate']:.3f};"
         f"bytes={adm['bytes']}"),
    ]


def bank_dispatch(scale=0.01, seed=0, n_query=200_000):
    """FilterBank dispatch overhead vs a direct `query_keys` call: the
    name-lookup + telemetry accounting the serving layer pays per batch."""
    from repro.kernels import query_keys
    from repro.runtime.filter_bank import FilterBank

    rows = []
    ds = make_dataset("shalla", scale, seed)
    space_bytes = ds.n_pos * 10 // 8
    h = HABF.build(ds.pos_u64, ds.neg_u64, None, total_bytes=space_bytes,
                   k=3, seed=seed)
    bf = BloomFilter(space_bytes * 8, k=4)
    bf.insert(ds.pos_u64)
    bank = FilterBank()
    bank.register("admission", h)
    bank.register("dedup", bf)
    rng = np.random.default_rng(seed)
    q = rng.choice(np.concatenate([ds.pos_u64, ds.neg_u64]), n_query)

    def bench(fn, name):
        fn()  # compile/warm
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        rows.append((f"bank_{name}", dt / n_query * 1e6,
                     f"keys_per_s={n_query / dt:.3g}"))

    bench(lambda: np.asarray(query_keys(h.to_artifact(), q)), "direct_habf")
    bench(lambda: np.asarray(bank.query("admission", q)), "dispatch_habf")
    bench(lambda: bank.query_batch({"admission": q, "dedup": q}),
          "batch_2filters")
    tel = bank.telemetry("admission")
    rows.append(("bank_telemetry", 0.0,
                 f"queries={tel['queries']};kernel={tel['kernel_queries']}"))
    bank.close()
    return rows
