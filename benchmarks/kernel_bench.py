"""Device-kernel + serving throughput benchmarks (CSV rows)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import HABF, BloomFilter
from repro.core.datasets import make_dataset


def kernel_throughput(scale=0.01, seed=0, n_query=200_000):
    """Pallas (interpret) vs pure-jnp ref vs host numpy, keys/s.

    NOTE: on this CPU container the Pallas kernel runs in interpret mode —
    the number demonstrates correctness plumbing, not TPU performance; the
    jnp ref path is the portable production fallback."""
    import jax
    from repro.kernels import query
    from repro.core.hashing import split_u64
    import jax.numpy as jnp

    rows = []
    ds = make_dataset("shalla", scale, seed)
    h = HABF.build(ds.pos_u64, ds.neg_u64, None,
                   total_bytes=ds.n_pos * 10 // 8, k=3, seed=seed)
    rng = np.random.default_rng(seed)
    q = rng.choice(np.concatenate([ds.pos_u64, ds.neg_u64]), n_query)
    lo, hi = split_u64(q)
    lo, hi = jnp.asarray(lo), jnp.asarray(hi)
    habf_art = h.to_artifact()
    bloom_art = h.bf.to_artifact()

    def bench(fn, name):
        fn()  # compile/warm
        t0 = time.perf_counter()
        jax.block_until_ready(fn()) if name != "host" else fn()
        dt = time.perf_counter() - t0
        rows.append((f"kernel_{name}", dt / n_query * 1e6,
                     f"keys_per_s={n_query / dt:.3g}"))

    bench(lambda: h.query(q), "host")
    bench(lambda: query(habf_art, lo, hi, use_kernel=False), "habf_jnp_ref")
    bench(lambda: query(habf_art, lo, hi, use_kernel=True),
          "habf_pallas_interp")
    bench(lambda: query(bloom_art, lo, hi, use_kernel=False),
          "bloom_jnp_ref")
    bench(lambda: query(bloom_art, lo, hi, use_kernel=True),
          "bloom_pallas_interp")
    return rows


def serving_throughput(seed=0):
    from repro.launch.serve import run
    out = run(arch="qwen3-0.6b", reduced=True, batch=8, prompt_len=48,
              gen=16, seed=seed)
    fs = out["filter_stats"]
    return [
        ("serve_tokens_per_s", 1e6 / max(out["tokens_per_s"], 1e-9),
         f"tokens_per_s={out['tokens_per_s']:.1f}"),
        ("serve_admission", 0.0,
         f"admitted={out['admitted']}/{out['batch']}"),
        ("serve_filter_habf_vs_bf", 0.0,
         f"habf_wfpr={fs['habf_weighted_fpr']:.2e};"
         f"bf_wfpr={fs['bf_weighted_fpr']:.2e}"),
    ]
