"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--scale 0.01] [--only fig12]
  PYTHONPATH=src python -m benchmarks.run --full        # paper-scale
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import paper_figs, kernel_bench


def suites(scale: float, seed: int, with_learned: bool):
    return {
        "fig8": lambda: paper_figs.fig8_theory_bound(scale, seed),
        "fig9": lambda: paper_figs.fig9_parameters(scale, seed),
        "fig10": lambda: paper_figs.fig10_11_fpr_vs_space(
            scale, seed, skew=0.0, dataset="shalla",
            with_learned=with_learned, tag="fig10"),
        "fig10_ycsb": lambda: paper_figs.fig10_11_fpr_vs_space(
            scale, seed, skew=0.0, dataset="ycsb", with_learned=False,
            tag="fig10"),
        "fig11": lambda: paper_figs.fig10_11_fpr_vs_space(
            scale, seed, skew=1.0, dataset="shalla",
            with_learned=with_learned, tag="fig11"),
        "fig11_ycsb": lambda: paper_figs.fig10_11_fpr_vs_space(
            scale, seed, skew=1.0, dataset="ycsb", with_learned=False,
            tag="fig11"),
        "fig12": lambda: paper_figs.fig12_time(scale, seed),
        "fig13": lambda: paper_figs.fig13_skew(scale, seed),
        "fig14": lambda: paper_figs.fig14_hash_impls(max(0.001, scale / 5),
                                                     seed),
        "fig15": lambda: paper_figs.fig15_memory(scale / 2, seed),
        "kernels": lambda: kernel_bench.kernel_throughput(scale, seed),
        "serving": lambda: kernel_bench.serving_throughput(seed),
        "bank": lambda: kernel_bench.bank_dispatch(scale, seed),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01,
                    help="dataset scale vs paper size (1.0 = paper)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (slow)")
    ap.add_argument("--no-learned", dest="learned", action="store_false")
    args = ap.parse_args()
    scale = 1.0 if args.full else args.scale

    table = suites(scale, args.seed, args.learned)
    names = args.only.split(",") if args.only else list(table)
    print("name,us_per_call,derived")
    failed = 0
    for name in names:
        t0 = time.time()
        try:
            for row in table[name]():
                print(f"{row[0]},{row[1]:.3f},{row[2]}", flush=True)
        except Exception as e:  # pragma: no cover
            failed += 1
            print(f"{name},0,ERROR={e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} took {time.time() - t0:.1f}s", flush=True)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
