"""Unified filter API tests: registry completeness, the Filter protocol,
pytree artifact round-trips (flatten/unflatten, jit-through, npz
save/load), and host-vs-device query parity for every registered filter."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (Filter, SpaceBudget, available_filters, make_filter,
                        zipf_costs)
from repro.core.datasets import make_shalla
from repro.kernels import load_artifact, query, query_keys

U64_FILTERS = ("habf", "fhabf", "bloom", "bloom-double", "xor", "wbf")
LEARNED_FILTERS = ("lbf", "slbf", "adabf")


@pytest.fixture(scope="module")
def keysets():
    rng = np.random.default_rng(7)
    keys = rng.choice(np.uint64(1) << np.uint64(62), 8000,
                      replace=False).astype(np.uint64)
    pos, neg = keys[:4000], keys[4000:]
    unseen = rng.integers(1 << 40, 1 << 61, 2000).astype(np.uint64)
    return pos, neg, unseen


@pytest.fixture(scope="module")
def string_ds():
    return make_shalla(scale=0.002, seed=3)


@pytest.fixture(scope="module")
def learned_filters(string_ds):
    ds = string_ds
    space = SpaceBudget.from_bits_per_key(12, ds.n_pos)
    return {name: make_filter(name, ds.pos_strs, ds.neg_strs, space=space,
                              seed=0)
            for name in LEARNED_FILTERS}


def test_registry_lists_every_paper_filter():
    names = available_filters()
    for expect in U64_FILTERS + LEARNED_FILTERS:
        assert expect in names
    with pytest.raises(KeyError):
        make_filter("no-such-filter", np.zeros(1, np.uint64), space=64)


@pytest.mark.parametrize("name", U64_FILTERS)
def test_registry_builds_and_zero_fnr(name, keysets):
    pos, neg, _ = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    f = make_filter(name, pos, neg, zipf_costs(len(neg), 1.0, 2),
                    space=space, seed=0)
    assert isinstance(f, Filter)          # runtime-checkable protocol
    assert f.query(pos).all(), "false negative on built positives"
    assert f.query(neg).mean() < 0.2
    assert f.size_bytes > 0
    assert isinstance(f.summary(), dict)


@pytest.mark.parametrize("name", LEARNED_FILTERS)
def test_registry_learned_zero_fnr(name, string_ds, learned_filters):
    ds, f = string_ds, learned_filters[name]
    assert isinstance(f, Filter)
    assert f.query(ds.pos_strs).all(), "false negative on built positives"
    assert f.size_bytes > 0
    assert isinstance(f.summary(), dict)


def test_learned_filters_reject_u64_only_keys(keysets):
    pos, neg, _ = keysets
    with pytest.raises(TypeError):
        make_filter("lbf", pos, neg, space=SpaceBudget(4096))


def test_string_keys_accepted_everywhere(string_ds):
    ds = string_ds
    space = SpaceBudget.from_bits_per_key(10, ds.n_pos)
    f = make_filter("habf", ds.pos_strs, ds.neg_strs, space=space, seed=0)
    # string and fingerprint queries agree
    np.testing.assert_array_equal(f.query(ds.pos_strs), f.query(ds.pos_u64))
    assert f.query(ds.pos_strs).all()


@pytest.mark.parametrize("name", U64_FILTERS)
def test_host_device_parity(name, keysets):
    pos, neg, unseen = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    f = make_filter(name, pos, neg, zipf_costs(len(neg), 1.0, 2),
                    space=space, seed=0)
    for probe in (pos, neg, unseen):
        host = np.asarray(f.query(probe))
        dev = np.asarray(query_keys(f, probe))
        np.testing.assert_array_equal(host, dev)


@pytest.mark.parametrize("name", LEARNED_FILTERS)
def test_host_device_parity_learned(name, string_ds, learned_filters):
    ds, f = string_ds, learned_filters[name]
    probe = ds.pos_strs[:500] + ds.neg_strs[:500]
    host = np.asarray(f.query(probe))
    dev = np.asarray(query_keys(f, probe))
    np.testing.assert_array_equal(host, dev)


def test_wbf_skewed_pos_costs_keeps_zero_fnr(keysets):
    # low-cost keys are inserted with k_e < k_bar and fall out of the
    # cache; the uncached fallback must stay a prefix of every inserted
    # hash set so the protocol's zero-FNR contract holds without costs
    pos, neg, _ = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    f = make_filter("wbf", pos, space=space,
                    pos_costs=zipf_costs(len(pos), 1.5, 9))
    assert f.query(pos).all(), "cost-skewed WBF lost zero FNR"
    host = np.asarray(f.query(neg))
    np.testing.assert_array_equal(host, np.asarray(query_keys(f, neg)))


def test_empty_key_batch_everywhere(string_ds, learned_filters):
    u64 = np.zeros((0,), np.uint64)
    space = SpaceBudget(1024)
    f = make_filter("bloom", np.arange(1, 100, dtype=np.uint64), space=space)
    assert f.query(u64).shape == (0,)
    assert np.asarray(query_keys(f, u64)).shape == (0,)
    lbf = learned_filters["lbf"]
    assert lbf.query([]).shape == (0,)
    assert np.asarray(query_keys(lbf, [])).shape == (0,)


def test_wbf_query_costs_parity(keysets):
    pos, neg, _ = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    f = make_filter("wbf", pos, space=space,
                    pos_costs=zipf_costs(len(pos), 1.0, 5))
    qcosts = zipf_costs(len(neg), 1.0, 6)
    host = np.asarray(f.query(neg, qcosts))
    dev = np.asarray(query_keys(f, neg, costs=qcosts))
    np.testing.assert_array_equal(host, dev)


# ---------------------------------------------------------------------------
# artifact pytree mechanics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", U64_FILTERS)
def test_artifact_pytree_roundtrip(name, keysets):
    pos, neg, _ = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    art = make_filter(name, pos, neg, space=space, seed=0).to_artifact()
    leaves, treedef = jax.tree_util.tree_flatten(art)
    assert leaves, "artifact must expose its tables as pytree leaves"
    art2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert art == art2
    # static meta rides aux_data: scalar-free leaves only
    assert all(hasattr(l, "shape") for l in leaves)


@pytest.mark.parametrize("name", ("habf", "bloom", "bloom-double"))
def test_artifact_jit_through_and_device_put(name, keysets):
    pos, neg, unseen = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    f = make_filter(name, pos, neg, space=space, seed=0)
    art = jax.device_put(f.to_artifact())

    # an artifact passes through jit boundaries as a normal pytree arg
    @jax.jit
    def probe(a, lo, hi):
        from repro.kernels.dispatch import (bloom_artifact_ref,
                                            habf_artifact_ref)
        fn = habf_artifact_ref if name == "habf" else bloom_artifact_ref
        return fn(a, lo, hi)

    from repro.core.hashing import split_u64
    lo, hi = split_u64(unseen)
    out = np.asarray(probe(art, jnp.asarray(lo), jnp.asarray(hi)))
    np.testing.assert_array_equal(out, np.asarray(f.query(unseen)))


@pytest.mark.parametrize("name", U64_FILTERS)
def test_artifact_npz_save_load(name, keysets, tmp_path):
    pos, neg, unseen = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    f = make_filter(name, pos, neg, space=space, seed=0)
    art = f.to_artifact()
    p = tmp_path / f"{name}.npz"
    art.save(p)
    art2 = load_artifact(p)
    assert art == art2
    np.testing.assert_array_equal(np.asarray(query_keys(art2, unseen)),
                                  np.asarray(f.query(unseen)))


def test_learned_artifact_npz_save_load(string_ds, learned_filters,
                                        tmp_path):
    ds = string_ds
    f = learned_filters["slbf"]          # nested: params + backup + pre
    art = f.to_artifact()
    p = tmp_path / "slbf.npz"
    art.save(p)
    art2 = load_artifact(p)
    assert art == art2
    probe = ds.pos_strs[:300] + ds.neg_strs[:300]
    np.testing.assert_array_equal(np.asarray(query_keys(art2, probe)),
                                  np.asarray(f.query(probe)))


def test_ngram_artifact_query_shape():
    from repro.kernels import build_blocklist
    rng = np.random.default_rng(0)
    grams = rng.integers(0, 1000, (32, 4)).astype(np.int32)
    art = build_blocklist(grams, 1 << 14, k=3)
    tokens = rng.integers(0, 1000, (2, 64)).astype(np.int32)
    out = np.asarray(query(art, jnp.asarray(tokens)))
    assert out.shape == (2, 64)
    with pytest.raises(TypeError):
        query_keys(art, np.zeros(4, np.uint64))
