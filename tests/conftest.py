"""Test bootstrap.

Prefers the real `hypothesis` (declared in pyproject's test extra); in
offline containers where it is absent, installs the deterministic
fallback from tests/_hypothesis_fallback.py under the same module name so
the property-test modules still collect and run.
"""
import sys
from pathlib import Path

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).parent))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
