import numpy as np
import pytest

from repro.data.pipeline import (DataPipeline, PipelineConfig,
                                 build_dedup_filter, doc_fingerprints)


def _cfg(**kw):
    base = dict(vocab=1000, seq_len=32, global_batch=8, seed=0)
    base.update(kw)
    return PipelineConfig(**base)


def test_deterministic_and_resumable():
    p1 = DataPipeline(_cfg())
    batches = [p1.batch_at(s) for s in range(5)]
    # resume from step 3 reproduces identical data
    p2 = DataPipeline(_cfg(), start_step=3)
    np.testing.assert_array_equal(batches[3]["tokens"], p2.batch_at(3)["tokens"])
    # different seed differs
    p3 = DataPipeline(_cfg(seed=1))
    assert (p3.batch_at(0)["tokens"] != batches[0]["tokens"]).any()


def test_labels_are_shifted_tokens():
    p = DataPipeline(_cfg())
    b = p.batch_at(0)
    assert b["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_partitions_batch():
    full = DataPipeline(_cfg()).batch_at(0)["doc_ids"]
    h0 = DataPipeline(_cfg(n_hosts=2, host_id=0, global_batch=8)).batch_at(0)
    h1 = DataPipeline(_cfg(n_hosts=2, host_id=1, global_batch=8)).batch_at(0)
    np.testing.assert_array_equal(np.concatenate([h0["doc_ids"],
                                                  h1["doc_ids"]]), full)


def test_dedup_skips_known_duplicates():
    dup_ids = np.arange(0, 64, dtype=np.uint64)
    clean = np.arange(1 << 20, (1 << 20) + 4000, dtype=np.uint64)
    habf = build_dedup_filter(dup_ids, clean, total_bytes=1 << 14)
    # zero FNR: every known duplicate is filtered
    assert habf.query(doc_fingerprints(dup_ids)).all()
    p = DataPipeline(_cfg(global_batch=8), dedup=habf)
    b = p.batch_at(0)  # doc ids 0..7 are all in the duplicate set
    assert p.skipped == 8
    assert (b["doc_ids"] >= (1 << 60)).all()  # replaced with fresh docs


def test_prefetch_thread():
    p = DataPipeline(_cfg())
    ref = [p.batch_at(s)["tokens"] for s in range(3)]
    q = DataPipeline(_cfg())
    q.start_prefetch()
    got = [next(q)["tokens"] for _ in range(3)]
    q.stop()
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)
