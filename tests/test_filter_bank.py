"""FilterBank: multi-filter dispatcher, placement, telemetry, swap — and
the serve-loop gate regression tests (the formerly dead `generate` wiring
must fire)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SpaceBudget, make_filter, zipf_costs
from repro.kernels import build_blocklist, query_keys
from repro.runtime.filter_bank import FilterBank, PlacementPolicy, place


def _keysets(seed=0, n=4000):
    rng = np.random.default_rng(seed)
    keys = rng.choice(np.uint64(1) << np.uint64(62), 2 * n,
                      replace=False).astype(np.uint64)
    return keys[:n], keys[n:]


@pytest.fixture()
def bank3():
    """A bank serving 3 heterogeneous artifact types + an n-gram entry."""
    pos, neg = _keysets()
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    habf = make_filter("habf", pos, neg, zipf_costs(len(neg), 1.0, 1),
                       space=space, seed=0)
    bloom = make_filter("bloom", pos, space=space)
    xor = make_filter("xor", pos, space=space)
    bank = FilterBank()
    bank.register("admission", habf)
    bank.register("dedup", bloom)
    bank.register("cache", xor)
    rng = np.random.default_rng(3)
    bank.register("blocklist", build_blocklist(
        rng.integers(0, 1000, (32, 4)).astype(np.int32), 1 << 14, k=3))
    yield bank, {"admission": habf, "dedup": bloom, "cache": xor}, pos, neg
    bank.close()


def test_bank_serves_three_types_one_entrypoint(bank3):
    bank, filters, pos, neg = bank3
    probe = np.concatenate([pos[:500], neg[:500]])
    for name, filt in filters.items():
        hits = np.asarray(bank.query(name, probe))
        np.testing.assert_array_equal(hits, filt.query(probe))
        t = bank.telemetry(name)
        assert t["queries"] == 1 and t["keys"] == len(probe)
        assert t["kernel_queries"] == 1 and t["ref_queries"] == 0
        assert t["hits"] == int(filt.query(probe).sum())
        assert 0.0 < t["hit_rate"] < 1.0
        assert t["bytes"] > 0
    # the ngram entry is served behind the same entrypoint
    toks = np.random.default_rng(4).integers(0, 1000, (4, 64))
    out = np.asarray(bank.query("blocklist", toks))
    assert out.shape == (4, 64)
    assert bank.telemetry("blocklist")["keys"] == 4 * 64


def test_bank_query_batch_and_path_attribution(bank3):
    bank, filters, pos, neg = bank3
    out = bank.query_batch({"dedup": pos[:100], "cache": neg[:100]},
                           use_kernel=False)
    assert np.asarray(out["dedup"]).all()            # zero FNR
    assert bank.telemetry("dedup")["ref_queries"] == 1
    assert bank.telemetry("cache")["ref_queries"] == 1
    # a direct query_keys against the registered artifact is attributed
    # to the entry via the dispatch telemetry hook
    query_keys(bank.artifact("dedup"), pos[:50])
    t = bank.telemetry("dedup")
    assert t["queries"] == 2 and t["kernel_queries"] == 1
    # ...but keys/hits stay a matched pair (the hook never sees outcomes,
    # so direct dispatches must not dilute hit_rate)
    assert t["keys"] == 100
    assert t["hit_rate"] == t["hits"] / 100


def test_bank_estimated_fp_cost(bank3):
    bank, filters, pos, neg = bank3
    costs = zipf_costs(len(neg), 1.5, 7)
    hits = np.asarray(bank.query("dedup", neg, costs=costs))
    t = bank.telemetry("dedup")
    # est FP cost = cost-weighted hit mass (the weighted-FPR numerator):
    # every hit on a negative stream is a false positive
    assert t["est_fp_cost"] == pytest.approx((costs * hits).sum())


def test_bank_swap_double_buffered(bank3):
    bank, filters, pos, neg = bank3
    space = SpaceBudget.from_bits_per_key(10, len(neg))
    old = bank.swap("dedup", make_filter("bloom", neg, space=space))
    # old artifact returned intact for in-flight closures
    assert np.asarray(query_keys(old, pos[:200])).all()
    # the name now serves the new key set
    assert np.asarray(bank.query("dedup", neg[:200])).all()
    t = bank.telemetry("dedup")
    assert t["version"] == 2
    with pytest.raises(ValueError):
        bank.register("dedup", make_filter("bloom", pos, space=space))


def test_bank_placement_shards_large_replicates_small():
    pos, _ = _keysets(1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # tiny threshold: the words table crosses it, the hash constants don't
    bank = FilterBank(mesh=mesh, policy=PlacementPolicy(shard_bytes=1024))
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    bf = make_filter("bloom", pos, space=space)
    bank.register("dedup", bf)
    t = bank.telemetry("dedup")
    assert t["placement"]["sharded"] == ["words"]
    assert set(t["placement"]["replicated"]) == {"c1", "c2", "mul"}
    # small filter below the threshold: fully replicated
    small = make_filter("bloom", pos[:100],
                        space=SpaceBudget.from_bits_per_key(8, 100))
    bank.register("small", small)
    assert bank.telemetry("small")["placement"]["sharded"] == []
    # placed artifacts still answer identically to the host filters
    np.testing.assert_array_equal(
        np.asarray(bank.query("dedup", pos[:300])), bf.query(pos[:300]))
    bank.close()


def test_place_report_and_none_mesh():
    pos, _ = _keysets(2, n=1000)
    art = make_filter("bloom", pos,
                      space=SpaceBudget.from_bits_per_key(10, len(pos))
                      ).to_artifact()
    placed, rep = place(art, None)
    assert placed is art and rep["sharded"] == []
    assert rep["bytes"] == sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(art))


# ---------------------------------------------------------------------------
# serve-loop regressions: the gates must actually fire under `generate`
# ---------------------------------------------------------------------------

def _tiny_model(batch=2, prompt_len=8, steps=6, seed=0):
    from repro.configs import get_config
    from repro.models.model import Model
    cfg = get_config("qwen3-0.6b", reduced=True)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    prompt = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
    cache = model.init_cache(batch, prompt_len + steps + 1)
    return cfg, model, params, prompt, cache


def test_generate_blocklist_regression_fires():
    """A blocklisted n-gram of the model's own (deterministic, greedy)
    output must be reported `blocked` by `generate` — the wiring that used
    to be dead code (gates ignored, window never threaded)."""
    from repro.runtime.serve_loop import generate
    B, P, S, n = 2, 8, 6, 4
    cfg, model, params, prompt, cache = _tiny_model(B, P, S)
    toks, _, rep = generate(model, params, prompt, cache, S)
    assert rep == {}                       # no gates -> empty report
    seq = np.concatenate([np.asarray(prompt["tokens"]), np.asarray(toks)],
                         axis=1)
    # blocked[:, j] flags the n-gram ending at generated token j, i.e. at
    # seq position P + j; blocklist two grams — one ending mid-stream and
    # one spanning the prompt/generation boundary (ends at the prefill
    # emission, j=0)
    j = 2
    grams = np.stack([seq[0, P + j + 1 - n: P + j + 1],
                      seq[0, P + 1 - n: P + 1]])
    bank = FilterBank()
    bank.register("blocklist", build_blocklist(grams, 1 << 14, k=3))
    cache2 = model.init_cache(B, P + S + 1)
    toks2, _, rep2 = generate(model, params, prompt, cache2, S, bank=bank)
    np.testing.assert_array_equal(np.asarray(toks2), np.asarray(toks))
    assert rep2["blocked"].shape == (B, S)
    assert rep2["blocked"][0, j], "blocklisted n-gram not reported blocked"
    assert rep2["blocked"][0, 0], "boundary-spanning n-gram not blocked"
    assert rep2["blocked_ngrams"] >= 2
    # the outcome is accounted into the bank's telemetry
    t = bank.telemetry("blocklist")
    assert t["fused_queries"] == 1 and t["hits"] == rep2["blocked_ngrams"]
    bank.close()


def test_generate_string_named_gate_telemetry():
    """Gates named by string resolve to that bank entry — and the outcome
    is accounted to the entry actually used, not a hardcoded name."""
    from repro.runtime.serve_loop import generate
    B, P, S = 2, 8, 4
    cfg, model, params, prompt, cache = _tiny_model(B, P, S)
    bank = FilterBank()
    bank.register("toxic_bl", build_blocklist(
        np.arange(16).reshape(4, 4).astype(np.int32), 1 << 14, k=3))
    toks, _, rep = generate(model, params, prompt, cache, S, bank=bank,
                            blocklist="toxic_bl")
    assert rep["blocked"].shape == (B, S)
    t = bank.telemetry("toxic_bl")
    assert t["fused_queries"] == 1 and t["keys"] == B * S
    bank.close()


def test_generate_admission_regression_fires():
    """The admission gate must probe under `generate` (it used to be
    ignored: prefill was hardwired gateless)."""
    from repro.runtime.serve_loop import generate
    B = 4
    cfg, model, params, prompt, cache = _tiny_model(batch=B)
    pos, neg = _keysets(5, n=2000)
    habf = make_filter("habf", pos, neg, zipf_costs(len(neg), 1.0, 1),
                       space=SpaceBudget.from_bits_per_key(10, len(pos)),
                       seed=0)
    mix = np.concatenate([pos[:B // 2], neg[:B - B // 2]])
    prompt["prefix_lo"] = jnp.asarray(mix & 0xFFFFFFFF, jnp.uint32)
    prompt["prefix_hi"] = jnp.asarray(mix >> np.uint64(32), jnp.uint32)
    bank = FilterBank()
    bank.register("admission", habf)
    toks, _, rep = generate(model, params, prompt, cache, 4, bank=bank)
    np.testing.assert_array_equal(rep["admit"], habf.query(mix))
    assert rep["admit"][: B // 2].all()    # zero FNR on the cached half
    assert bank.telemetry("admission")["fused_queries"] == 1
    bank.close()


def test_decode_zero_padding_masked():
    """A blocklist entry colliding with the zero left-padding must NOT
    fire while the window is still filling — and without the fill mask it
    would have (the bug this pins down)."""
    from repro.runtime.serve_loop import make_decode_step
    B, P, n = 2, 8, 4
    cfg, model, params, prompt, cache = _tiny_model(B, P)
    from repro.runtime.serve_loop import make_prefill_step
    out, cache = jax.jit(make_prefill_step(model))(params, prompt, cache)
    tok0 = out["next_token"]
    # learn the first decode emission, then blocklist the padded window
    # [0, 0, tok0, tok1] that the first decode step will probe
    step_plain = jax.jit(make_decode_step(model))
    o, _ = step_plain(params, tok0, cache, jnp.int32(P))
    tok1 = o["next_token"]
    gram = np.array([[0, 0, int(tok0[0]), int(tok1[0])]], np.int32)
    bl = build_blocklist(gram, 1 << 14, k=3)
    step = jax.jit(make_decode_step(model, blocklist=bl))
    window = jnp.zeros((B, n), jnp.int32).at[:, -1].set(tok0)
    # without the fill mask the zero-padded window spuriously matches
    o_buggy, _ = step(params, tok0, cache, jnp.int32(P), window)
    assert bool(o_buggy["blocked"][0]), "collision fixture did not collide"
    # with window_fill=1 (only tok0 is real) the probe is masked
    o_fixed, _ = step(params, tok0, cache, jnp.int32(P), window,
                      jnp.int32(1))
    assert not o_fixed["blocked"].any()
    assert int(o_fixed["window_fill"]) == 2
    # once the window genuinely fills, real hits still fire: walk fills
    # forward and confirm the mask opens at n valid tokens
    fill = jnp.int32(n - 1)
    o_full, _ = step(params, tok0, cache, jnp.int32(P), window, fill)
    assert int(o_full["window_fill"]) == n
    np.testing.assert_array_equal(np.asarray(o_full["blocked"]),
                                  np.asarray(o_buggy["blocked"]))


def test_decode_window_shift_contract():
    """`last_window` ends at the *previous* token; the step shifts left
    and appends its own emission (the docstring used to claim the caller
    had already appended it)."""
    from repro.runtime.serve_loop import make_decode_step, seed_window
    B, P, n = 2, 8, 4
    cfg, model, params, prompt, cache = _tiny_model(B, P)
    from repro.runtime.serve_loop import make_prefill_step
    out, cache = jax.jit(make_prefill_step(model))(params, prompt, cache)
    tok0 = out["next_token"]
    window, fill = seed_window(prompt["tokens"], tok0, n)
    # seeded window = trailing n-1 prompt tokens + the prefill emission
    np.testing.assert_array_equal(
        np.asarray(window),
        np.concatenate([np.asarray(prompt["tokens"])[:, -(n - 1):],
                        np.asarray(tok0)[:, None]], axis=1))
    assert int(fill) == n
    bl = build_blocklist(np.zeros((1, n), np.int32), 1 << 14, k=3)
    step = jax.jit(make_decode_step(model, blocklist=bl))
    o, _ = step(params, tok0, cache, jnp.int32(P), window, fill)
    np.testing.assert_array_equal(
        np.asarray(o["window"]),
        np.concatenate([np.asarray(window)[:, 1:],
                        np.asarray(o["next_token"])[:, None]], axis=1))


def test_seed_window_short_prompt_pads_and_counts():
    from repro.runtime.serve_loop import seed_window
    prompt = jnp.asarray([[7, 9]], jnp.int32)          # T=2 < n-1=4
    tok0 = jnp.asarray([3], jnp.int32)
    win, fill = seed_window(prompt, tok0, n=5)
    np.testing.assert_array_equal(np.asarray(win), [[0, 0, 7, 9, 3]])
    assert int(fill) == 3


def test_seed_window_ragged_prompts_per_row_fill():
    """Left-padded ragged batches get a per-row fill, so padded rows stay
    probe-masked until their window holds n real tokens."""
    from repro.runtime.serve_loop import blocklist_probe, seed_window
    n = 4
    # row 0 has only 2 real tokens (left-padded with id 0), row 1 is full
    prompt = jnp.asarray([[0, 0, 0, 5, 6], [1, 2, 3, 4, 5]], jnp.int32)
    tok0 = jnp.asarray([7, 7], jnp.int32)
    win, fill = seed_window(prompt, tok0, n, prompt_lens=[2, 5])
    np.testing.assert_array_equal(np.asarray(fill), [3, n])
    # a blocklist entry colliding with row 0's padded window [0,5,6,7]
    bl = build_blocklist(np.asarray([[0, 5, 6, 7]], np.int32), 1 << 14, k=3)
    raw = np.asarray(blocklist_probe(bl, win))
    assert raw[0], "collision fixture did not collide"
    masked = raw & (np.asarray(fill) >= n)
    assert not masked[0] and int(fill[1]) == n   # row 0 masked, row 1 live


def test_generate_caller_decode_step_coordination():
    """A caller-built decode step keeps its baked-in gate live under
    generate, and a gateless step cannot silently swallow a resolved
    blocklist."""
    from repro.runtime.serve_loop import generate, make_decode_step
    B, P, S = 2, 8, 4
    cfg, model, params, prompt, cache = _tiny_model(B, P, S)
    bl = build_blocklist(np.arange(16).reshape(4, 4).astype(np.int32),
                         1 << 14, k=3)
    step = make_decode_step(model, blocklist=bl)
    toks, _, rep = generate(model, params, prompt, cache, S,
                            decode_step=step)
    assert rep["blocked"].shape == (B, S)      # the step's gate is live
    bank = FilterBank()
    bank.register("blocklist", bl)
    with pytest.raises(ValueError, match="without one"):
        generate(model, params, prompt, model.init_cache(B, P + S + 1), S,
                 bank=bank, decode_step=make_decode_step(model))
    other = build_blocklist(np.arange(12).reshape(3, 4).astype(np.int32),
                            1 << 14, k=3)
    with pytest.raises(ValueError, match="different blocklist"):
        generate(model, params, prompt, model.init_cache(B, P + S + 1), S,
                 bank=bank, decode_step=make_decode_step(model,
                                                         blocklist=other))
    bank.close()
