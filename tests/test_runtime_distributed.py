"""Multi-device runtime tests.  Each test spawns a subprocess with
--xla_force_host_platform_device_count=8 (device count locks at first jax
init, so the main pytest process must stay single-device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("RESULT::" + json.dumps(out))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=500, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    for line in r.stdout.splitlines():
        if line.startswith("RESULT::"):
            return json.loads(line[len("RESULT::"):])
    raise AssertionError(f"no RESULT:: in output\n{r.stdout}")


def test_sharded_train_step_matches_single_device():
    out = _run("""
        from repro.configs import REDUCED
        from repro.models.model import Model
        from repro.optimizer.adamw import AdamW
        from repro.runtime import sharding as sh
        from repro.runtime.train_loop import (make_train_step,
            param_shardings, batch_shardings)

        cfg = REDUCED["qwen3-0.6b"]
        model = Model(cfg)
        params, specs = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        state = opt.init(params)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
        step = make_train_step(model, opt)

        # single device reference
        p1, s1, m1 = jax.jit(step)(params, state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with sh.use_mesh(mesh):
            p_sh = param_shardings(mesh, specs, shapes=params)
            b_sh = batch_shardings(mesh, batch)
            params_d = jax.device_put(params, p_sh)
            batch_d = jax.device_put(batch, b_sh)
            state_d = opt.init(params_d)
            p2, s2, m2 = jax.jit(step, in_shardings=(p_sh, None, b_sh))(
                params_d, state_d, batch_d)
        out = {"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
               "n_dev": len(jax.devices())}
    """)
    assert out["n_dev"] == 8
    assert abs(out["loss1"] - out["loss2"]) < 5e-3, out


def test_grad_accumulation_equivalence():
    out = _run("""
        from repro.configs import REDUCED
        from repro.models.model import Model
        from repro.optimizer.adamw import AdamW
        from repro.runtime.train_loop import make_train_step

        cfg = REDUCED["qwen2-1.5b"]
        model = Model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        rng = np.random.default_rng(1)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
        p1, _, m1 = jax.jit(make_train_step(model, opt))(params, opt.init(params), batch)
        p4, _, m4 = jax.jit(make_train_step(model, opt, accum=4))(params, opt.init(params), batch)
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
        out = {"loss1": float(m1["loss"]), "loss4": float(m4["loss"]), "max_dp": d}
    """, devices=1)
    assert abs(out["loss1"] - out["loss4"]) < 5e-3
    assert out["max_dp"] < 5e-3


def test_compressed_dp_matches_uncompressed_direction():
    out = _run("""
        from repro.configs import REDUCED
        from repro.models.model import Model
        from repro.optimizer.adamw import AdamW
        from repro.runtime.compression import (make_compressed_train_step,
                                               init_error)

        cfg = REDUCED["qwen3-0.6b"]
        model = Model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3, clip_norm=None)
        rng = np.random.default_rng(2)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32)}
        mesh = jax.make_mesh((8,), ("data",))
        cstep = make_compressed_train_step(model, opt, mesh)
        err = init_error(params)
        with mesh:
            p2, s2, err, m2 = jax.jit(cstep)(params, opt.init(params), err, batch)
            # one more step to exercise error feedback
            p3, s3, err, m3 = jax.jit(cstep)(p2, s2, err, batch)

        from repro.runtime.train_loop import make_train_step
        p1, _, m1 = jax.jit(make_train_step(model, AdamW(lr=1e-3, clip_norm=None)))(
            params, opt.init(params), batch)
        # parameter update direction agrees (int8 quantization noise is small)
        import numpy as np
        num = den1 = den2 = 0.0
        for a, b, p0 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2),
                            jax.tree.leaves(params)):
            da = np.asarray(a - p0, np.float64).ravel()
            db = np.asarray(b - p0, np.float64).ravel()
            num += (da * db).sum(); den1 += (da*da).sum(); den2 += (db*db).sum()
        cos = num / (den1**0.5 * den2**0.5 + 1e-12)
        out = {"cos": float(cos), "loss_c": float(m2["loss"]),
               "loss_u": float(m1["loss"]), "loss_c2": float(m3["loss"])}
    """)
    assert out["cos"] > 0.90, out  # int8 EF noise through Adam per-coord normalization
    assert abs(out["loss_c"] - out["loss_u"]) < 1e-2
    assert out["loss_c2"] < out["loss_c"] + 0.5


def test_elastic_restore_across_device_counts(tmp_path):
    # save on 8 devices (4x2 mesh)
    out = _run(f"""
        from repro.configs import REDUCED
        from repro.models.model import Model
        from repro.runtime import sharding as sh
        from repro.runtime.train_loop import param_shardings
        from repro.checkpoint.checkpointer import Checkpointer

        cfg = REDUCED["qwen2-1.5b"]
        model = Model(cfg)
        params, specs = model.init(jax.random.PRNGKey(3))
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        p_sh = param_shardings(mesh, specs, shapes=params)
        params = jax.device_put(params, p_sh)
        ck = Checkpointer({str(tmp_path)!r})
        ck.save(11, params)
        out = {{"sum": float(sum(jnp.sum(jnp.abs(l.astype(jnp.float32)))
               for l in jax.tree.leaves(params)))}}
    """)
    saved_sum = out["sum"]
    # restore on 2 devices (1x2 mesh)
    out2 = _run(f"""
        from repro.configs import REDUCED
        from repro.models.model import Model
        from repro.runtime.fault_tolerance import elastic_restore
        from repro.checkpoint.checkpointer import Checkpointer

        cfg = REDUCED["qwen2-1.5b"]
        model = Model(cfg)
        params, specs = model.init(jax.random.PRNGKey(99))  # different init
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        ck = Checkpointer({str(tmp_path)!r})
        restored, man = elastic_restore(ck, params, mesh, specs, shapes=params)
        out = {{"sum": float(sum(jnp.sum(jnp.abs(l.astype(jnp.float32)))
               for l in jax.tree.leaves(restored))), "step": man["step"],
               "n_shards": len(jax.tree.leaves(restored)[0].sharding.device_set)}}
    """, devices=2)
    assert out2["step"] == 11
    assert abs(out2["sum"] - saved_sum) / saved_sum < 1e-5


def test_filter_bank_mesh_placement():
    """FilterBank on a real 4x2 mesh: the big Bloom words table is
    sharded over `model`, the small HABF stays fully replicated, and both
    still answer exactly like the host filters."""
    out = _run("""
        from repro.core import SpaceBudget, make_filter, zipf_costs
        from repro.runtime.filter_bank import FilterBank, PlacementPolicy

        rng = np.random.default_rng(0)
        keys = rng.choice(np.uint64(1) << np.uint64(62), 8000,
                          replace=False).astype(np.uint64)
        pos, neg = keys[:4000], keys[4000:]
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        bank = FilterBank(mesh=mesh)
        # 1 MiB words table (2^23 bits) crosses the default shard threshold
        big = make_filter("bloom", pos, space=SpaceBudget(1 << 20))
        small = make_filter("habf", pos, neg, zipf_costs(len(neg), 1.0, 1),
                            space=SpaceBudget.from_bits_per_key(10, len(pos)),
                            seed=0)
        big_art = bank.register("dedup", big)
        small_art = bank.register("admission", small)
        probe = np.concatenate([pos[:1000], neg[:1000]])
        hits_big = np.asarray(bank.query("dedup", probe))
        hits_small = np.asarray(bank.query("admission", probe))
        shard0 = big_art.words.addressable_shards[0].data
        out = {
            "big_spec": str(big_art.words.sharding.spec),
            "big_ndev": len(big_art.words.sharding.device_set),
            "shard_frac": shard0.shape[0] / big_art.words.shape[0],
            "small_specs": sorted({str(l.sharding.spec) for l in
                                   jax.tree.leaves(small_art)}),
            "parity_big": bool((hits_big == np.asarray(
                big.query(probe))).all()),
            "parity_small": bool((hits_small == np.asarray(
                small.query(probe))).all()),
            "sharded": bank.telemetry("dedup")["placement"]["sharded"],
            "replicated_adm": bank.telemetry(
                "admission")["placement"]["sharded"] == [],
        }
    """)
    assert out["big_spec"] == "PartitionSpec('model',)"
    assert out["big_ndev"] == 8          # replicated over data, split over model
    assert out["shard_frac"] == 0.5      # model axis extent 2
    assert out["small_specs"] == ["PartitionSpec()"]
    assert out["parity_big"] and out["parity_small"]
    assert out["sharded"] == ["words"] and out["replicated_adm"]


def test_gpipe_matches_sequential():
    out = _run("""
        from repro.runtime.pipeline import gpipe
        from jax.sharding import PartitionSpec as P

        S, n_micro, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, d, d)) * 0.3
        params = {"w": w}

        def apply_stage(p, h):
            return jnp.tanh(h @ p["w"])

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
        # sequential reference
        ref = x
        for s in range(S):
            ref = jnp.tanh(ref @ w[s])

        mesh = jax.make_mesh((4, 2), ("pipe", "data"))
        y = gpipe(apply_stage, params, x, mesh, axis="pipe")
        err = float(jnp.max(jnp.abs(y - ref)))
        out = {"err": err}
    """)
    assert out["err"] < 1e-5, out
