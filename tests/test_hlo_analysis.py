"""Unit tests for the trip-count-scaled HLO analyzer on a synthetic
module (the roofline's data source — deliverable g)."""
from repro.launch import hlo_analysis as H

HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %t0 = (s32[], f32[8,16]{1,0}) tuple(%i0, %x)
  %w = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %big = f32[32,64]{1,0} constant({...})
  %v = f32[8,64]{1,0} dot(%x, %big), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_trip_count_scaling():
    r = H.analyze(HLO)
    # while-body dot: 2*8*16*16 = 4096 flops x 10 trips = 40960
    # entry dot: 2*8*64*16 = 16384 (x1)... lhs contracting dim 1 -> 16
    assert r["flops"] == 10 * 2 * 8 * 16 * 16 + 2 * 8 * 64 * 16
    ar = r["collectives"]["all-reduce"]
    assert ar["count"] == 10
    assert ar["bytes"] == 10 * 8 * 16 * 4


def test_shape_parsing():
    assert H._nbytes(H._shapes_in("f32[8,16]{1,0}")) == 512
    assert H._nbytes(H._shapes_in("(bf16[4,4]{1,0}, s32[])")) == 36
    assert H._nbytes(H._shapes_in("pred[100]")) == 100


def test_promoted_all_reduce_counted_at_wire_dtype():
    hlo = HLO.replace("to_apply=%add", "to_apply=%add.clone_promoted")
    r = H.analyze(hlo)
    assert r["collectives"]["all-reduce"]["bytes"] == 10 * 8 * 16 * 4 // 2


def test_memory_proxy_counts_dots():
    r = H.analyze(HLO)
    # body dot: (operands 8*16*4 + 16*16*4 + out 8*16*4) x 10 trips
    body_dot = (512 + 1024 + 512) * 10
    entry_dot = 512 + 32 * 64 * 4 + 8 * 64 * 4
    body_ar = 2 * 512 * 10      # collectives touch HBM (read+write)
    assert r["hbm_bytes"] == body_dot + entry_dot + body_ar
