import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HashExpressor


@given(st.integers(0, 2**32), st.integers(2, 5), st.integers(4, 60))
@settings(max_examples=25, deadline=None)
def test_insert_then_query_exact(seed, k, n_keys):
    """Zero-FNR invariant: every successfully inserted key retrieves its
    exact phi, even after later insertions (walks never clobbered)."""
    rng = np.random.default_rng(seed)
    omega = 40 * n_keys  # roomy
    hx = HashExpressor(omega, k=k)
    inserted = {}
    keys = rng.integers(0, 1 << 63, n_keys).astype(np.uint64)
    for key in keys:
        phi = rng.choice(22, size=k, replace=False)
        ok, _ = hx.try_insert(key, phi, rng, commit=True)
        if ok:
            inserted[int(key)] = set(phi.tolist())
    assert inserted, "at least one insertion should succeed"
    got_phi, valid = hx.query(np.asarray(list(inserted), np.uint64))
    assert valid.all()
    for row, key in zip(got_phi, inserted):
        assert set(row.tolist()) == inserted[key]


def test_tentative_plan_does_not_mutate():
    rng = np.random.default_rng(0)
    hx = HashExpressor(128, k=3)
    before = (hx.hashidx.copy(), hx.endbit.copy())
    ok, plan = hx.plan_insert(np.uint64(12345), [1, 5, 9], rng)
    assert ok
    np.testing.assert_array_equal(hx.hashidx, before[0])
    np.testing.assert_array_equal(hx.endbit, before[1])
    hx.commit_plan(plan)
    assert hx.hashidx.sum() > 0 and hx.endbit.sum() == 1


def test_uninserted_keys_mostly_invalid():
    rng = np.random.default_rng(3)
    hx = HashExpressor(4096, k=3)
    for i in range(40):
        hx.try_insert(np.uint64(i), rng.choice(22, 3, replace=False), rng)
    probe = rng.integers(1 << 40, 1 << 62, 5000).astype(np.uint64)
    _, valid = hx.query(probe)
    # F_h <= t/omega (paper §III-F): 40/4096 ~ 1%
    assert valid.mean() <= 3 * 40 / 4096 + 0.01


def test_insertion_failure_when_crowded():
    rng = np.random.default_rng(4)
    hx = HashExpressor(8, k=3)
    fails = 0
    for i in range(50):
        ok, _ = hx.try_insert(np.uint64(i), rng.choice(22, 3, replace=False), rng)
        fails += not ok
    assert fails > 0  # a tiny table must reject most insertions


def test_shared_cells_save_writes():
    """Case-2 sharing: inserting a key whose needed hash already sits in the
    mapped cell requires fewer new writes."""
    rng = np.random.default_rng(5)
    hx = HashExpressor(64, k=2)
    total_writes = 0
    for i in range(30):
        ok, nw = hx.try_insert(np.uint64(i * 7919), [i % 22, (i + 3) % 22], rng)
        if ok:
            total_writes += nw
    nonempty = int((hx.hashidx != 0).sum())
    assert nonempty <= total_writes  # sharing implies fewer cells than writes+endbits
