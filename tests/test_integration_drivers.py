"""End-to-end driver integration tests (the examples, as assertions)."""
import numpy as np
import pytest


def test_train_driver_loss_decreases_and_resumes(tmp_path):
    from repro.launch.train import run
    out = run(arch="qwen3-0.6b", reduced=True, steps=40, batch=4, seq=48,
              lr=5e-3, ckpt_dir=str(tmp_path), save_every=20, dedup=True,
              seed=0, log_every=100)
    assert out["final_loss"] < out["losses"][0]
    # resume continues from the step-40 checkpoint
    out2 = run(arch="qwen3-0.6b", reduced=True, steps=50, batch=4, seq=48,
               lr=5e-3, ckpt_dir=str(tmp_path), resume=True, save_every=20,
               seed=0, log_every=100)
    assert len(out2["losses"]) == 10  # only steps 40..50 run
    assert out2["final_loss"] < out["losses"][0]


def test_serve_driver_admission_and_filters():
    from repro.launch.serve import run
    out = run(arch="qwen2-1.5b", reduced=True, batch=8, prompt_len=32,
              gen=8, seed=1)
    # exactly the cached half of the batch admitted (zero FNR + no FP here)
    assert out["admitted"] == 4
    fs = out["filter_stats"]
    assert fs["zero_fnr"]
    assert fs["habf_weighted_fpr"] <= fs["bf_weighted_fpr"]
    assert out["generated"].shape == (8, 8)
    # both gates route through one FilterBank with live telemetry
    tel = out["bank_telemetry"]
    assert set(tel) == {"admission", "blocklist"}
    assert tel["admission"]["fused_queries"] == 1
    assert tel["admission"]["hits"] == 4 and tel["admission"]["keys"] == 8
    assert tel["blocklist"]["keys"] == 8 * 8   # one probe per emitted token


def test_serve_driver_derives_blocklist_window_from_n():
    """The decode window width follows the registered blocklist's n-gram
    order (it used to be hardcoded to 4)."""
    from repro.launch.serve import run
    out = run(arch="qwen3-0.6b", reduced=True, batch=2, prompt_len=16,
              gen=6, seed=3, blocklist_n=6)
    assert out["generated"].shape == (2, 6)
    assert out["bank_telemetry"]["blocklist"]["keys"] == 2 * 6


def test_serve_driver_mamba():
    """Serving loop works for the attention-free family too."""
    from repro.launch.serve import run
    out = run(arch="mamba2-780m", reduced=True, batch=4, prompt_len=24,
              gen=6, seed=2, habf_gate=False, blocklist=False)
    assert out["generated"].shape == (4, 6)
    assert np.isfinite(out["tokens_per_s"])
