import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hashing


def test_family_deterministic():
    f1 = hashing.make_family(8, seed=42)
    f2 = hashing.make_family(8, seed=42)
    for k in f1:
        np.testing.assert_array_equal(f1[k], f2[k])
    assert (hashing.make_family(8, seed=43)["c1"] != f1["c1"]).any()
    assert (f1["mul"] % 2 == 1).all()  # odd multipliers


@given(st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=64),
       st.integers(0, hashing.DEFAULT_N_HASH - 1),
       st.sampled_from([97, 1 << 10, 12345, 1 << 24]))
@settings(max_examples=30, deadline=None)
def test_host_device_agree(keys, hidx, m):
    """numpy (construction) and jnp (query) hashing must agree bit-exactly."""
    keys = np.asarray(keys, np.uint64)
    host = hashing.hash_index_np(keys, hidx, m)
    lo, hi = hashing.split_u64(keys)
    fam = hashing.FAMILY
    dev = hashing.hash_index_jnp(jnp.asarray(lo), jnp.asarray(hi),
                                 jnp.uint32(fam["c1"][hidx]),
                                 jnp.uint32(fam["c2"][hidx]),
                                 jnp.uint32(fam["mul"][hidx]), m)
    np.testing.assert_array_equal(host, np.asarray(dev))
    assert (host >= 0).all() and (host < m).all()


def test_umulhi32_matches_u64():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << 32, 1000, dtype=np.uint64)
    b = rng.integers(1, 1 << 32, 1000, dtype=np.uint64)
    want = ((a * b) >> np.uint64(32)).astype(np.uint32)
    got = hashing.umulhi32_jnp(jnp.asarray(a.astype(np.uint32)),
                               jnp.asarray(b.astype(np.uint32)))
    np.testing.assert_array_equal(want, np.asarray(got))


def test_hash_uniformity():
    """chi^2-ish sanity: bucket counts close to uniform."""
    keys = np.arange(200_000, dtype=np.uint64)
    m = 256
    for hidx in [0, 7, 21]:
        idx = hashing.hash_index_np(keys, hidx, m)
        counts = np.bincount(idx, minlength=m)
        expected = len(keys) / m
        assert abs(counts.mean() - expected) < 1e-6
        assert counts.std() < 4 * np.sqrt(expected)


def test_hash_functions_differ():
    keys = np.arange(1000, dtype=np.uint64)
    idx0 = hashing.hash_index_np(keys, 0, 1 << 20)
    idx1 = hashing.hash_index_np(keys, 1, 1 << 20)
    assert (idx0 != idx1).mean() > 0.99


def test_fingerprint_bytes():
    fps = hashing.fingerprint_bytes(["a", "b", "ab", "ba", "", "a" * 100])
    assert len(set(fps.tolist())) == 6
    again = hashing.fingerprint_bytes(["a", "b"])
    np.testing.assert_array_equal(fps[:2], again)


def test_double_hash_spread():
    keys = np.arange(50_000, dtype=np.uint64)
    i0 = hashing.fastrange_np(hashing.double_hash_value_np(keys, 0), 1 << 16)
    i5 = hashing.fastrange_np(hashing.double_hash_value_np(keys, 5), 1 << 16)
    assert (i0 != i5).mean() > 0.99
