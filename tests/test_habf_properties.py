"""Hypothesis property tests on the paper's core invariants (§III/§IV),
beyond the example-based tests in test_habf.py."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import HABF, BloomFilter, weighted_fpr, zipf_costs


def _sets(seed, n):
    rng = np.random.default_rng(seed)
    keys = rng.choice(np.uint64(1) << np.uint64(62), 2 * n,
                      replace=False).astype(np.uint64)
    return keys[:n], keys[n:], rng


@given(st.integers(0, 2**32), st.floats(0.0, 2.5), st.integers(8, 14))
@settings(max_examples=10, deadline=None)
def test_tpjo_never_hurts_round1_fpr(seed, skew, bpk):
    """TPJO only converts collision keys to negatives: the optimized
    first-round (weighted) FPR must be <= the pre-optimization FPR of the
    same filter under H0 (Eq. 9: F*_bf = F_bf - t/|O|)."""
    pos, neg, _ = _sets(seed, 3000)
    costs = zipf_costs(len(neg), skew, seed)
    h = HABF.build(pos, neg, costs, total_bytes=3000 * bpk // 8, k=3,
                   seed=seed)
    # rebuild the unoptimized round-1 filter: same m, same H0, all pos
    bf0 = BloomFilter(h.bf.bits.m, h.config.k)
    bf0.insert(pos)
    w_before = weighted_fpr(bf0.query(neg), costs)
    w_after = weighted_fpr(h.bf.query(neg), costs)
    assert w_after <= w_before + 1e-12


@given(st.integers(0, 2**32))
@settings(max_examples=8, deadline=None)
def test_optimized_count_matches_round1_gain(seed):
    """Eq. 9 exactly: surviving round-1 FPs == collisions seen minus those
    optimized minus those fixed as side effects of earlier adjustments."""
    pos, neg, _ = _sets(seed, 3000)
    h = HABF.build(pos, neg, None, total_bytes=3000 * 10 // 8, k=3,
                   seed=seed)
    s = h.summary()
    still_fp = int(h.bf.query(neg).sum())
    assert still_fp == (s["n_collision_total"] - s["n_optimized"]
                        - s["n_side_fixed"])


@given(st.integers(0, 2**32), st.integers(2, 5))
@settings(max_examples=8, deadline=None)
def test_hashexpressor_fpr_bound(seed, k):
    """§III-F: F_h <= t / omega for keys never inserted."""
    pos, neg, rng = _sets(seed, 3000)
    h = HABF.build(pos, neg, None, total_bytes=3000 * 10 // 8, k=k,
                   seed=seed)
    t = h.hx.n_inserted
    probe = rng.integers(1 << 40, 1 << 61, 20_000).astype(np.uint64)
    _, valid = h.hx.query(probe)
    # 3-sigma slack on the binomial around the t/omega bound
    bound = t / h.hx.omega
    sigma = np.sqrt(max(bound, 1e-9) / len(probe))
    assert valid.mean() <= bound + 4 * sigma + 1e-4


@given(st.integers(0, 2**32))
@settings(max_examples=6, deadline=None)
def test_device_host_query_agree_everywhere(seed):
    """The jnp two-round query must agree with the host query on positive,
    negative, and never-seen keys (any divergence breaks zero-FNR on TPU)."""
    from repro.kernels import query_keys
    pos, neg, rng = _sets(seed, 2000)
    h = HABF.build(pos, neg, zipf_costs(len(neg), 1.0, seed),
                   total_bytes=2000 * 10 // 8, k=3, seed=seed)
    unseen = rng.integers(1 << 40, 1 << 61, 4000).astype(np.uint64)
    for keys in (pos, neg, unseen):
        host = h.query(keys)
        dev = np.asarray(query_keys(h, keys, use_kernel=False))
        np.testing.assert_array_equal(host, dev)


@given(st.integers(0, 2**32), st.floats(0.5, 3.0))
@settings(max_examples=6, deadline=None)
def test_cost_ordering_respected(seed, skew):
    """TPJO optimizes in descending cost order: the total cost of
    surviving false positives should be <= the cost of the same NUMBER of
    the most expensive initial collisions (cheap keys get sacrificed)."""
    pos, neg, _ = _sets(seed, 3000)
    costs = zipf_costs(len(neg), skew, seed)
    h = HABF.build(pos, neg, costs, total_bytes=3000 * 9 // 8, k=3,
                   seed=seed)
    surviving = h.bf.query(neg)
    n_surv = int(surviving.sum())
    if n_surv == 0:
        return
    bf0 = BloomFilter(h.bf.bits.m, h.config.k)
    bf0.insert(pos)
    init_fp_costs = np.sort(costs[bf0.query(neg)])[::-1]
    surv_cost = costs[surviving].sum()
    worst_case = init_fp_costs[:n_surv].sum()
    assert surv_cost <= worst_case + 1e-9
