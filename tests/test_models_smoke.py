"""Per-architecture smoke tests on REDUCED configs (assignment: small
layers/width/experts/tables, one forward/train step on CPU, assert output
shapes + no NaNs).  Full configs are exercised only via the dry-run."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED, SHAPES
from repro.models import Model

ARCH_NAMES = sorted(REDUCED)


def _batch(cfg, B=2, T=32, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_frames, cfg.d_model)), cfg.cdtype)
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_img_tokens, cfg.d_model)), cfg.cdtype)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_loss_finite(name):
    cfg = REDUCED[name]
    model = Model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch(cfg)
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    # a random model should sit near ln(vocab)
    assert 0.3 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_reduces_loss(name):
    from repro.optimizer.adamw import AdamW
    cfg = REDUCED[name]
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    batch = _batch(cfg, B=2, T=16)
    opt = AdamW(lr=5e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for _ in range(5):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """Greedy decode after prefill must match teacher-forced logits."""
    cfg = REDUCED[name]
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    B, T = 2, 16
    S = T + 8 + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    batch = _batch(cfg, B=B, T=T, rng=rng)
    cache = model.init_cache(B, S)
    prefill_batch = dict(batch)
    prefill_batch.pop("labels")
    logits_p, cache = jax.jit(model.prefill)(params, prefill_batch, cache)
    assert logits_p.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits_p, np.float32)).all()

    # decode two tokens; check shapes and finiteness
    pos0 = T + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)
    decode = jax.jit(model.decode)
    for i in range(2):
        logits_d, cache = decode(params, tok, cache, jnp.int32(pos0 + i))
        assert logits_d.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits_d, np.float32)).all()
        tok = jnp.argmax(logits_d, axis=-1).astype(jnp.int32)


def test_decode_matches_prefill_dense():
    """Stronger equivalence on a dense arch: prefill logits at position t
    == decode logits after feeding tokens one by one."""
    cfg = REDUCED["qwen3-0.6b"]
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    B, T = 1, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)

    # teacher-forced full logits
    from repro.models import layers as ly, transformer as tf
    x = ly.embed_tokens(cfg, params, tokens)
    h, _, _ = tf.backbone(cfg, params, x, jnp.arange(T))
    full_logits = ly.logits_from_hidden(cfg, params, h)

    # prefill first token, then decode the rest step by step
    cache = model.init_cache(B, T + 1)
    lp, cache = model.prefill(params, {"tokens": tokens[:, :1]}, cache)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full_logits[:, 0]),
                               rtol=2e-4, atol=2e-4)
    for t in range(1, T):
        ld, cache = model.decode(params, tokens[:, t], cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(ld), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3)


def test_ssm_decode_matches_prefill():
    """Mamba-2: chunked SSD prefill state == step-by-step recurrence."""
    cfg = REDUCED["mamba2-780m"]
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(6))
    rng = np.random.default_rng(7)
    B, T = 1, 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)

    from repro.models import layers as ly
    x = ly.embed_tokens(cfg, params, tokens)
    from repro.models.model import _SSMModule
    h, _ = _SSMModule._backbone(cfg, params, x)
    full_logits = ly.logits_from_hidden(cfg, params, h)

    cache = model.init_cache(B, T + 1)
    lp, cache = model.prefill(params, {"tokens": tokens[:, :4]}, cache)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full_logits[:, 3]),
                               rtol=1e-3, atol=1e-3)
    for t in range(4, T):
        ld, cache = model.decode(params, tokens[:, t], cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(ld), np.asarray(full_logits[:, t]),
            rtol=5e-3, atol=5e-3)


def test_param_counts_sane():
    from repro.configs import ARCHS
    pc = ARCHS["llama3-405b"].param_counts()
    assert 3.8e11 < pc["total"] < 4.3e11, pc
    pc = ARCHS["llama4-maverick-400b-a17b"].param_counts()
    assert 3.3e11 < pc["total"] < 4.8e11, pc
    assert 1.2e10 < pc["active"] < 2.4e10, pc
    pc = ARCHS["qwen3-0.6b"].param_counts()
    assert 4e8 < pc["total"] < 9e8, pc
    pc = ARCHS["mamba2-780m"].param_counts()
    assert 5e8 < pc["total"] < 1.1e9, pc
