import numpy as np
import pytest

from repro.core import (XorFilter, xor_filter_for_space, WeightedBloomFilter,
                        zipf_costs, weighted_fpr)
from repro.core.learned import build_lbf, build_adabf
from repro.core.datasets import make_shalla, make_ycsb


def _keys(rng, n):
    return rng.choice(np.uint64(1) << np.uint64(62), size=n,
                      replace=False).astype(np.uint64)


def test_xor_filter_no_fn_and_fpr():
    rng = np.random.default_rng(0)
    keys = _keys(rng, 20_000)
    pos, neg = keys[:10_000], keys[10_000:]
    xf = XorFilter(pos, fingerprint_bits=8)
    assert xf.query(pos).all()
    fpr = xf.query(neg).mean()
    assert fpr < 3 * 2.0 ** -8  # ~1/256
    xf12 = XorFilter(pos, fingerprint_bits=12)
    assert xf12.query(neg).mean() < fpr


def test_xor_filter_space_sizing():
    rng = np.random.default_rng(1)
    pos = _keys(rng, 10_000)
    xf = xor_filter_for_space(pos, total_bytes=10_000 * 10 // 8)
    assert xf.query(pos).all()
    assert 6 <= xf.fp_bits <= 9  # 10 bpk / 1.23 ~ 8


def test_wbf_no_fn_and_cost_sensitivity():
    rng = np.random.default_rng(2)
    keys = _keys(rng, 30_000)
    pos, neg = keys[:15_000], keys[15_000:]
    pos_costs = zipf_costs(len(pos), 1.0, seed=1)
    wbf = WeightedBloomFilter(15_000 * 10, k_bar=5, k_max=10)
    wbf.insert(pos, pos_costs)
    assert wbf.query(pos, pos_costs).all()
    neg_costs = zipf_costs(len(neg), 1.0, seed=2)
    w = weighted_fpr(wbf.query(neg, neg_costs), neg_costs)
    assert w < 0.2


def test_lbf_no_fn():
    ds = make_shalla(scale=0.004, seed=0)
    total = ds.n_pos * 12 // 8
    lbf = build_lbf(ds.pos_strs, ds.pos_u64, ds.neg_strs, ds.neg_u64,
                    total_bytes=total, model="mlp", seed=0)
    assert lbf.query(ds.pos_strs, ds.pos_u64).all()
    assert lbf.query(ds.neg_strs, ds.neg_u64).mean() < 0.3


def test_slbf_no_fn():
    ds = make_shalla(scale=0.003, seed=1)
    total = ds.n_pos * 12 // 8
    slbf = build_lbf(ds.pos_strs, ds.pos_u64, ds.neg_strs, ds.neg_u64,
                     total_bytes=total, model="mlp", seed=0, sandwich=True)
    assert slbf.query(ds.pos_strs, ds.pos_u64).all()


def test_adabf_no_fn():
    ds = make_shalla(scale=0.003, seed=2)
    total = ds.n_pos * 12 // 8
    ada = build_adabf(ds.pos_strs, ds.pos_u64, ds.neg_strs, ds.neg_u64,
                      total_bytes=total, model="mlp", seed=0)
    assert ada.query(ds.pos_strs, ds.pos_u64).all()


def test_datasets_disjoint_and_deterministic():
    for mk in (make_shalla, make_ycsb):
        a = mk(scale=0.002, seed=5)
        b = mk(scale=0.002, seed=5)
        np.testing.assert_array_equal(a.pos_u64, b.pos_u64)
        assert not set(a.pos_u64.tolist()) & set(a.neg_u64.tolist())
