"""Paper §IV bounds verified empirically (the code behind Fig. 8)."""
import numpy as np
import pytest

from repro.core import HABF, BloomFilter, theory


def _build(b, k, n=8000, seed=0):
    rng = np.random.default_rng(seed)
    keys = rng.choice(np.uint64(1) << np.uint64(62), 2 * n,
                      replace=False).astype(np.uint64)
    pos, neg = keys[:n], keys[n:]
    h = HABF.build(pos, neg, None, total_bytes=int(n * b / 8), k=k, seed=seed)
    return h, pos, neg


@pytest.mark.parametrize("k", [2, 4, 6])
def test_fbf_star_upper_bound_holds(k):
    """Eq. 19: measured F*_bf must stay below the theoretical upper bound."""
    b = 10
    h, pos, neg = _build(b, k)
    measured = h.bf.query(neg).mean()          # F*_bf: round-1 FPR after TPJO
    s = h.summary()
    fbf = s["n_collision_total"] / s["n_neg"]  # empirical pre-opt FPR
    # P'_c is bounded below via Theorem 4.1's P_xi (conservative proxy)
    p_c = theory.p_xi_lower(b * (1 - h.config.delta / (1 + h.config.delta)), k)
    bound = theory.fbf_star_upper(fbf, s["n_collision_initial"], p_c, k,
                                  s["omega"], s["n_neg"])
    assert measured <= bound + 1e-9, (measured, bound)


@pytest.mark.parametrize("b", [6, 10, 13])
def test_fbf_star_bound_vs_b(b):
    h, pos, neg = _build(b, 4)
    measured = h.bf.query(neg).mean()
    s = h.summary()
    fbf = s["n_collision_total"] / s["n_neg"]
    p_c = theory.p_xi_lower(b, 4)
    bound = theory.fbf_star_upper(fbf, s["n_collision_initial"], p_c, 4,
                                  s["omega"], s["n_neg"])
    assert measured <= bound + 1e-9


def test_p_xi_lower_monotone():
    # higher bits-per-key -> more singly-mapped units
    vals = [theory.p_xi_lower(b, 3) for b in (4, 8, 16)]
    assert vals[0] < vals[1] < vals[2]
    assert 0 < vals[0] < 1


def test_habf_fpr_close_to_fbf_star():
    """§III-F: with t << omega, F_habf ~ F*_bf."""
    h, pos, neg = _build(10, 3, n=12000)
    fbf_star = h.bf.query(neg).mean()
    fhabf = h.query(neg).mean()
    t = h.hx.n_inserted
    upper = theory.habf_fpr_upper(fbf_star, t, h.hx.omega)
    assert fhabf <= upper * 1.5 + 2e-3  # slack: F_h endbit-uniformity assumption
