"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle vs
host numpy, swept over shapes and table sizes — all through the unified
`kernels.query` / `query_keys` artifact surface."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import BloomFilter, DoubleHashBloomFilter, HABF, zipf_costs
from repro.kernels import build_blocklist, query, query_keys


def _keys(rng, n):
    return rng.integers(0, 1 << 63, n).astype(np.uint64)


@pytest.mark.parametrize("n_keys", [1, 7, 1024, 1025, 5000])
@pytest.mark.parametrize("m_bits", [4096, 1 << 18])
def test_bloom_kernel_matches_host(n_keys, m_bits):
    rng = np.random.default_rng(n_keys + m_bits)
    pos = _keys(rng, 2000)
    bf = BloomFilter(m_bits, k=4)
    bf.insert(pos)
    probe = np.concatenate([pos[:n_keys // 2], _keys(rng, n_keys - n_keys // 2)])
    host = bf.query(probe)
    dev = np.asarray(query_keys(bf, probe, use_kernel=True))
    ref = np.asarray(query_keys(bf, probe, use_kernel=False))
    np.testing.assert_array_equal(host, dev)
    np.testing.assert_array_equal(host, ref)


@pytest.mark.parametrize("k", [2, 3, 6])
def test_bloom_kernel_k_sweep(k):
    rng = np.random.default_rng(k)
    pos = _keys(rng, 1000)
    bf = BloomFilter(1 << 16, k=k)
    bf.insert(pos)
    probe = _keys(rng, 3000)
    np.testing.assert_array_equal(
        bf.query(probe), np.asarray(query_keys(bf, probe)))


def test_bloom_kernel_double_hash():
    rng = np.random.default_rng(5)
    pos = _keys(rng, 1000)
    bf = DoubleHashBloomFilter(1 << 16, k=4)
    bf.insert(pos)
    probe = np.concatenate([pos, _keys(rng, 2000)])
    # dispatch rides the artifact's static double_hash flag
    assert bf.to_artifact().double_hash
    np.testing.assert_array_equal(
        bf.query(probe), np.asarray(query_keys(bf, probe)))


@pytest.mark.parametrize("fast", [False, True])
@pytest.mark.parametrize("k", [2, 3, 4])
def test_habf_kernel_matches_host(fast, k):
    rng = np.random.default_rng(10 * k + fast)
    keys = rng.choice(np.uint64(1) << np.uint64(62), 12_000,
                      replace=False).astype(np.uint64)
    pos, neg = keys[:6000], keys[6000:]
    h = HABF.build(pos, neg, zipf_costs(len(neg), 1.0, 1),
                   total_bytes=6000 * 10 // 8, k=k, seed=0, fast=fast)
    probe = np.concatenate([pos[:2000], neg[:3000]])
    host = h.query(probe)
    dev = np.asarray(query_keys(h, probe, use_kernel=True))
    ref = np.asarray(query_keys(h, probe, use_kernel=False))
    np.testing.assert_array_equal(host, ref)
    np.testing.assert_array_equal(host, dev)
    # zero FNR holds on-device as well
    assert np.asarray(query_keys(h, pos)).all()


def test_deprecated_shims_removed():
    """PR-1 deprecation shims are gone for good: neither the kernels
    package nor the filters re-grow the stringly table surfaces."""
    import repro.kernels as kernels
    for name in ("bloom_query_u64", "habf_query_u64", "device_tables"):
        assert not hasattr(kernels, name), f"shim {name} resurfaced"
    rng = np.random.default_rng(11)
    pos, neg = _keys(rng, 200), _keys(rng, 200)
    bf = BloomFilter(1 << 12, k=4)
    bf.insert(pos)
    h = HABF.build(pos, neg, None, total_bytes=200 * 10 // 8, k=3, seed=0)
    for obj in (bf, h, h.hx):
        assert not hasattr(obj, "device_tables"), (
            f"{type(obj).__name__}.device_tables resurfaced")


@pytest.mark.parametrize("B,T,n", [(1, 64, 3), (4, 300, 4), (9, 1024, 5)])
def test_ngram_kernel_matches_ref(B, T, n):
    rng = np.random.default_rng(B * T + n)
    tokens = rng.integers(0, 32000, (B, T)).astype(np.int32)
    # blocklist: 50 n-grams actually present in the batch + 50 random
    rows = rng.integers(B, size=50)
    starts = rng.integers(0, T - n, 50)
    present = np.stack([tokens[b, s:s + n] for b, s in zip(rows, starts)])
    n_distinct = len({(int(b), int(s)) for b, s in zip(rows, starts)})
    absent = rng.integers(0, 32000, (50, n)).astype(np.int32)
    art = build_blocklist(np.concatenate([present, absent]), 1 << 16, k=4)
    assert art.n == n
    out_k = np.asarray(query(art, jnp.asarray(tokens), use_kernel=True))
    out_r = np.asarray(query(art, jnp.asarray(tokens), use_kernel=False))
    np.testing.assert_array_equal(out_k, out_r)
    # every inserted present n-gram must be flagged at its end position
    for b, s in zip(rows, starts):
        assert out_k[b, s + n - 1], f"missed inserted n-gram at {b},{s}"
    assert out_k.sum() >= n_distinct * 0.9
    assert not out_k[:, : n - 1].any()


@pytest.mark.parametrize("use_kernel", [True, False])
@pytest.mark.parametrize("B,T", [(0, 64), (2, 0), (0, 0), (2, 2), (1, 3)])
def test_ngram_empty_and_short_batches(B, T, use_kernel):
    """Bank-facing edge cases: empty batches (B=0 / T=0) and windows
    shorter than the n-gram order (T < n) return all-False with the input
    shape preserved, on both dispatch paths."""
    art = build_blocklist(np.arange(12).reshape(3, 4).astype(np.int32),
                          1 << 14, k=3)
    out = np.asarray(query(art, jnp.zeros((B, T), jnp.int32),
                           use_kernel=use_kernel))
    assert out.shape == (B, T)
    assert out.dtype == bool
    assert not out.any()


def test_query_keys_on_placed_artifact():
    """query/query_keys must accept an artifact that has already been
    device_put with a mesh sharding (the FilterBank placement path)."""
    import jax
    from repro.runtime.filter_bank import PlacementPolicy, place
    rng = np.random.default_rng(21)
    pos = _keys(rng, 4000)
    bf = BloomFilter(1 << 16, k=4)
    bf.insert(pos)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    placed, rep = place(bf.to_artifact(), mesh,
                        PlacementPolicy(shard_bytes=256))
    assert rep["sharded"] == ["words"]
    probe = np.concatenate([pos[:500], _keys(rng, 500)])
    host = bf.query(probe)
    np.testing.assert_array_equal(
        host, np.asarray(query_keys(placed, probe, use_kernel=True)))
    np.testing.assert_array_equal(
        host, np.asarray(query_keys(placed, probe, use_kernel=False)))


def test_ngram_no_false_negative_property():
    rng = np.random.default_rng(42)
    tokens = rng.integers(0, 1000, (2, 256)).astype(np.int32)
    n = 4
    grams = np.stack([tokens[i, s:s + n] for i in range(2)
                      for s in range(0, 256 - n, 17)])
    art = build_blocklist(grams, 1 << 15, k=3)
    out = np.asarray(query(art, jnp.asarray(tokens)))
    for i in range(2):
        for s in range(0, 256 - n, 17):
            assert out[i, s + n - 1], f"missed inserted n-gram at {i},{s}"
