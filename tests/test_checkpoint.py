import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 10, (3,)), jnp.int32),
                  "d": jnp.asarray(rng.normal(size=(2, 2)), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(7, t, aux={"data_step": 7})
    out, man = ck.restore(jax.tree.map(jnp.zeros_like, t))
    assert man["step"] == 7 and man["aux"]["data_step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_policy_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_async_save(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree(1)
    ck.save_async(5, t)
    ck.wait()
    out, man = ck.restore(t)
    assert man["step"] == 5


def test_atomicity_no_partial_dirs(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree())
    # a stale tmp dir from a "crashed" writer must not break anything
    stale = tmp_path / ".tmp_step_00000002_999"
    stale.mkdir()
    ck.save(2, _tree(2))
    assert ck.latest_step() == 2


def test_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree())
    with pytest.raises(AssertionError):
        ck.restore({"only": jnp.zeros((2,))})


def test_restore_with_shardings(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree(3)
    ck.save(1, t)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    out, _ = ck.restore(t, shardings=shardings)
    assert jax.tree.leaves(out)[0].sharding == NamedSharding(mesh, P())
