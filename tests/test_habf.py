import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (HABF, BloomFilter, weighted_fpr, zipf_costs,
                        optimal_k)


def _keys(rng, n):
    return rng.choice(np.uint64(1) << np.uint64(62), size=n,
                      replace=False).astype(np.uint64)


@given(st.integers(0, 2**32), st.integers(2, 5), st.booleans())
@settings(max_examples=12, deadline=None)
def test_zero_fnr(seed, k, fast):
    """The paper's headline structural guarantee (§III-E)."""
    rng = np.random.default_rng(seed)
    keys = _keys(rng, 4000)
    pos, neg = keys[:2000], keys[2000:]
    h = HABF.build(pos, neg, zipf_costs(len(neg), 1.0, seed),
                   total_bytes=2000 * 10 // 8, k=k, seed=seed, fast=fast)
    assert h.query(pos).all(), "HABF must have zero FNR"


def test_beats_bf_at_equal_space_skewed():
    rng = np.random.default_rng(7)
    keys = _keys(rng, 60_000)
    pos, neg = keys[:30_000], keys[30_000:]
    costs = zipf_costs(len(neg), 1.0, seed=3)
    total = 30_000 * 10 // 8
    h = HABF.build(pos, neg, costs, total_bytes=total, k=3, seed=0)
    bf = BloomFilter(total * 8, k=optimal_k(10))
    bf.insert(pos)
    w_habf = weighted_fpr(h.query(neg), costs)
    w_bf = weighted_fpr(bf.query(neg), costs)
    assert w_habf < w_bf, (w_habf, w_bf)
    assert w_habf < 0.5 * w_bf  # should be a lot better, paper shows >>2x


def test_beats_bf_uniform():
    rng = np.random.default_rng(8)
    keys = _keys(rng, 40_000)
    pos, neg = keys[:20_000], keys[20_000:]
    total = 20_000 * 10 // 8
    h = HABF.build(pos, neg, None, total_bytes=total, k=3, seed=0)
    bf = BloomFilter(total * 8, k=optimal_k(10))
    bf.insert(pos)
    assert h.query(neg).mean() < bf.query(neg).mean()


def test_fbf_star_identity():
    """Eq. 9: optimized collision keys become true negatives."""
    rng = np.random.default_rng(9)
    keys = _keys(rng, 30_000)
    pos, neg = keys[:15_000], keys[15_000:]
    h = HABF.build(pos, neg, None, total_bytes=15_000 * 10 // 8, k=3, seed=1)
    s = h.summary()
    # first-round FPR after optimization equals initial collisions minus
    # optimized, plus any collateral collisions that were not re-fixed
    round1_fp = int(h.bf.query(neg).sum())
    assert round1_fp <= s["n_collision_total"] - s["n_optimized"] + \
        s["n_failed_adjust"] + s["n_skipped_cost"] + 5


def test_two_round_query_structure():
    """Adjusted positives must fail round 1 and be rescued by round 2."""
    rng = np.random.default_rng(10)
    keys = _keys(rng, 20_000)
    pos, neg = keys[:10_000], keys[10_000:]
    h = HABF.build(pos, neg, None, total_bytes=10_000 * 10 // 8, k=3, seed=2)
    adj = h.adjusted
    assert adj.any(), "some positives should have been adjusted"
    round1 = h.bf.query(pos)  # H0 only
    assert not round1[adj].any(), "adjusted keys must fail the H0 round"
    assert h.query(pos).all()


def test_fast_variant_tradeoff():
    rng = np.random.default_rng(11)
    keys = _keys(rng, 30_000)
    pos, neg = keys[:15_000], keys[15_000:]
    costs = zipf_costs(len(neg), 1.0, seed=4)
    total = 15_000 * 10 // 8
    h = HABF.build(pos, neg, costs, total_bytes=total, k=3, seed=0)
    hf = HABF.build(pos, neg, costs, total_bytes=total, k=3, seed=0, fast=True)
    assert hf.query(pos).all()
    w, wf = weighted_fpr(h.query(neg), costs), weighted_fpr(hf.query(neg), costs)
    # paper: f-HABF ~1.5x worse than HABF but far better than BF
    bf = BloomFilter(total * 8, k=optimal_k(10))
    bf.insert(pos)
    wbf = weighted_fpr(bf.query(neg), costs)
    assert w <= wf <= wbf * 1.05


def test_space_accounting():
    h = HABF.build(np.arange(100, dtype=np.uint64),
                   np.arange(100, 200, dtype=np.uint64), None,
                   total_bytes=4096, k=3)
    # BF words + HashExpressor cells must stay within ~total (+word padding)
    assert h.size_bytes <= 4096 * 1.02 + 8
