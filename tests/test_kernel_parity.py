"""Interpret-mode parity matrix: every kernel (bloom, habf, ngram, xor,
wbf — plus the adabf/learned routes that ride them) against its pure-jnp
ref across batch sizes {0, 1, 7, 1024}, double_hash on/off, and skewed
ks/costs.  Also the `use_kernel` regression tests: the flag must reach
the kernel-capable op for every artifact type, never be silently ignored.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SpaceBudget, make_filter, zipf_costs
from repro.core.hashing import split_u64
from repro.kernels import build_blocklist, query, query_keys

BATCHES = (0, 1, 7, 1024)

# name -> (registry name, double_hash expected on the artifact)
U64_CASES = {
    "bloom": ("bloom", False),
    "bloom-double": ("bloom-double", True),
    "habf": ("habf", False),
    "fhabf": ("fhabf", True),
    "xor": ("xor", False),
    "wbf": ("wbf", False),
}


@pytest.fixture(scope="module")
def keysets():
    rng = np.random.default_rng(17)
    keys = rng.choice(np.uint64(1) << np.uint64(62), 8000,
                      replace=False).astype(np.uint64)
    return keys[:4000], keys[4000:]


@pytest.fixture(scope="module")
def filters(keysets):
    pos, neg = keysets
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    out = {}
    for name, (reg, _) in U64_CASES.items():
        kw = {}
        if reg == "wbf":
            # skewed insert costs: low-cost keys get k_e < k_bar and fall
            # out of the cache, exercising the k_fallback path
            kw["pos_costs"] = zipf_costs(len(pos), 1.5, 9)
        out[name] = make_filter(reg, pos, neg, zipf_costs(len(neg), 1.0, 2),
                                space=space, seed=0, **kw)
    return out


def _probe(pos, neg, batch):
    return np.concatenate([pos[:batch // 2], neg[:batch - batch // 2]])


@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("name", sorted(U64_CASES))
def test_kernel_matches_ref_and_host(name, batch, keysets, filters):
    pos, neg = keysets
    f = filters[name]
    assert f.to_artifact().meta().get("double_hash",
                                     False) == U64_CASES[name][1]
    probe = _probe(pos, neg, batch)
    kern = np.asarray(query_keys(f, probe, use_kernel=True, interpret=True))
    ref = np.asarray(query_keys(f, probe, use_kernel=False))
    host = np.asarray(f.query(probe))
    assert kern.shape == (batch,)
    np.testing.assert_array_equal(kern, ref)
    np.testing.assert_array_equal(kern, host)


@pytest.mark.parametrize("batch", BATCHES)
def test_wbf_kernel_skewed_query_costs(batch, keysets, filters):
    """Per-key ks from skewed query-side costs (ks_for_costs bucketing)."""
    pos, neg = keysets
    f = filters["wbf"]
    probe = _probe(pos, neg, batch)
    costs = zipf_costs(max(batch, 1), 1.5, 3)[:batch]
    kern = np.asarray(query_keys(f, probe, costs=costs, use_kernel=True,
                                 interpret=True))
    ref = np.asarray(query_keys(f, probe, costs=costs, use_kernel=False))
    host = np.asarray(f.query(probe, costs))
    np.testing.assert_array_equal(kern, ref)
    np.testing.assert_array_equal(kern, host)


@pytest.mark.parametrize("batch", BATCHES)
def test_wbf_kernel_extreme_ks(batch, keysets, filters):
    """ks pinned to the clamp bounds {1, k_max} lane-by-lane."""
    pos, neg = keysets
    f, art = filters["wbf"], filters["wbf"].to_artifact()
    probe = _probe(pos, neg, batch)
    lo, hi = split_u64(probe)
    ks = np.where(np.arange(batch) % 2 == 0, 1, art.k_max).astype(np.int32)
    kern = np.asarray(query(art, jnp.asarray(lo), jnp.asarray(hi),
                            ks=jnp.asarray(ks), use_kernel=True,
                            interpret=True))
    ref = np.asarray(query(art, jnp.asarray(lo), jnp.asarray(hi),
                           ks=jnp.asarray(ks), use_kernel=False))
    np.testing.assert_array_equal(kern, ref)


@pytest.mark.parametrize("B", (0, 1, 7, 16))
def test_ngram_kernel_matches_ref(B):
    rng = np.random.default_rng(B)
    T, n = 64, 4
    tokens = rng.integers(0, 5000, (B, T)).astype(np.int32)
    grams = rng.integers(0, 5000, (40, n)).astype(np.int32)
    art = build_blocklist(grams, 1 << 14, k=3)
    kern = np.asarray(query(art, jnp.asarray(tokens), use_kernel=True,
                            interpret=True))
    ref = np.asarray(query(art, jnp.asarray(tokens), use_kernel=False))
    assert kern.shape == (B, T)
    np.testing.assert_array_equal(kern, ref)


# ---------------------------------------------------------------------------
# learned routes (classifier scores + kernel probes)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def learned():
    from repro.core.datasets import make_shalla
    ds = make_shalla(scale=0.002, seed=3)
    space = SpaceBudget.from_bits_per_key(12, ds.n_pos)
    return ds, {name: make_filter(name, ds.pos_strs, ds.neg_strs,
                                  space=space, seed=0)
                for name in ("slbf", "adabf")}


@pytest.mark.parametrize("batch", (0, 1, 7, 512))
@pytest.mark.parametrize("name", ("slbf", "adabf"))
def test_learned_kernel_matches_ref_and_host(name, batch, learned):
    ds, filts = learned
    f = filts[name]
    probe = (ds.pos_strs + ds.neg_strs)[:batch]
    kern = np.asarray(query_keys(f, probe, use_kernel=True, interpret=True))
    ref = np.asarray(query_keys(f, probe, use_kernel=False))
    host = np.asarray(f.query(probe))
    assert kern.shape == (batch,)
    np.testing.assert_array_equal(kern, ref)
    np.testing.assert_array_equal(kern, host)


# ---------------------------------------------------------------------------
# use_kernel threading regression (dispatch docstring/behavior contract)
# ---------------------------------------------------------------------------

def _spy_on(monkeypatch, name):
    """Record the use_kernel= each dispatch-level op call receives."""
    from repro.kernels import dispatch
    calls = []
    real = getattr(dispatch, name)

    def spy(*a, **kw):
        calls.append(kw.get("use_kernel"))
        return real(*a, **kw)

    monkeypatch.setattr(dispatch, name, spy)
    return calls


@pytest.mark.parametrize("use_kernel", (True, False))
@pytest.mark.parametrize("name,op", [("xor", "xor_query"),
                                     ("wbf", "wbf_query"),
                                     ("bloom", "bloom_query")])
def test_use_kernel_never_silently_ignored(name, op, use_kernel, keysets,
                                           filters, monkeypatch):
    pos, neg = keysets
    calls = _spy_on(monkeypatch, op)
    query_keys(filters[name], neg[:32], use_kernel=use_kernel)
    assert calls == [use_kernel], (
        f"{op} must receive use_kernel={use_kernel}, got {calls}")


@pytest.mark.parametrize("use_kernel", (True, False))
def test_use_kernel_reaches_adabf_probe(use_kernel, learned, monkeypatch):
    ds, filts = learned
    calls = _spy_on(monkeypatch, "wbf_query")
    query_keys(filts["adabf"], ds.neg_strs[:16], use_kernel=use_kernel)
    assert calls == [use_kernel]


def test_use_kernel_reaches_learned_bloom_probes(learned, monkeypatch):
    ds, filts = learned
    calls = _spy_on(monkeypatch, "bloom_query")
    query_keys(filts["slbf"], ds.neg_strs[:16], use_kernel=True)
    # SLBF = pre + backup Bloom probes, both through the kernel op
    assert calls == [True, True]
