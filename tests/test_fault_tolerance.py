import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import (TrainSupervisor, InjectedFailure,
                                           StragglerPolicy)


def test_restart_from_checkpoint(tmp_path):
    ck = Checkpointer(tmp_path)
    sup = TrainSupervisor(ck, save_every=5, max_restarts=2)
    crashed = {"done": False}

    def step_fn(state, step):
        if step == 12 and not crashed["done"]:
            crashed["done"] = True
            raise InjectedFailure("host 3 died")
        return {"x": state["x"] + 1.0}

    def restore_fn(_):
        st, man = ck.restore({"x": jnp.zeros(())})
        return st, man["step"]

    out = sup.run(state={"x": jnp.zeros(())}, step_fn=step_fn, n_steps=20,
                  restore_fn=restore_fn)
    assert sup.report.restarts == 1
    assert len(sup.report.failures) == 1
    # restarted from step 10 checkpoint, so x = 20 - lost progress re-run
    assert float(out["x"]) == 20.0 - 10.0 + 10.0  # == 20 exactly


def test_max_restarts_exceeded(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(0, {"x": jnp.zeros(())})
    sup = TrainSupervisor(ck, save_every=100, max_restarts=1)

    def bad_step(state, step):
        raise InjectedFailure("persistent failure")

    def restore_fn(_):
        st, man = ck.restore({"x": jnp.zeros(())})
        return st, man["step"]

    with pytest.raises(InjectedFailure):
        sup.run(state={"x": jnp.zeros(())}, step_fn=bad_step, n_steps=5,
                restore_fn=restore_fn)


def test_straggler_detection(tmp_path):
    ck = Checkpointer(tmp_path)
    sup = TrainSupervisor(ck, save_every=1000,
                          straggler=StragglerPolicy(factor=5.0, window=16))

    def step_fn(state, step):
        time.sleep(0.05 if step == 14 else 0.002)
        return state

    sup.run(state={}, step_fn=step_fn, n_steps=16,
            restore_fn=lambda _: ({}, 0))
    assert len(sup.report.stragglers) >= 1
    assert sup.report.stragglers[0]["step"] == 14
