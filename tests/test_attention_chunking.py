"""Exactness of query-chunked attention (the A4 perf change) and the
segment-grouping knob (A5): both must be bit-for-bit semantics-preserving."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REDUCED
from repro.models import layers as ly
from repro.models.model import Model


def test_q_chunked_attention_matches_unchunked():
    cfg = REDUCED["mistral-nemo-12b"].replace(q_chunk=8)
    cfg_full = cfg.replace(q_chunk=1 << 30)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    l_chunk = float(Model(cfg).loss(params, batch))
    l_full = float(Model(cfg_full).loss(params, batch))
    assert abs(l_chunk - l_full) < 1e-5, (l_chunk, l_full)


def test_q_chunked_mla_matches_unchunked():
    cfg = REDUCED["deepseek-v2-lite-16b"].replace(q_chunk=8)
    cfg_full = cfg.replace(q_chunk=1 << 30)
    model = Model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    l_chunk = float(Model(cfg).loss(params, batch))
    l_full = float(Model(cfg_full).loss(params, batch))
    assert abs(l_chunk - l_full) < 1e-5


def test_layers_per_step_grouping_equivalent():
    """Grouping g layers per scan step must not change the math."""
    base = REDUCED["qwen3-0.6b"].replace(n_layers=4, layers_per_step=1,
                                         compute_dtype="float32")
    grouped = base.replace(layers_per_step=2)
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, base.vocab, (2, 16)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    p1, _ = Model(base).init(jax.random.PRNGKey(3))
    l1 = float(Model(base).loss(p1, batch))
    # rebuild grouped params from the same flat weights: grouping reshapes
    # the stack (4, ...) -> two stacks of (2, ...) under l0/l1 keys
    p2, _ = Model(grouped).init(jax.random.PRNGKey(3))

    def regroup(flat_seg):
        out = {"l0": {}, "l1": {}}
        def walk(src, d0, d1):
            for k, v in src.items():
                if isinstance(v, dict):
                    d0[k], d1[k] = {}, {}
                    walk(v, d0[k], d1[k])
                else:
                    d0[k] = v[0::2]
                    d1[k] = v[1::2]
        walk(flat_seg, out["l0"], out["l1"])
        return out

    p2 = dict(p2)
    p2["seg0"] = regroup(p1["seg0"]["l0"])
    for k in ("embed", "final_norm"):
        p2[k] = p1[k]
    if "lm_head" in p1:
        p2["lm_head"] = p1["lm_head"]
    l2 = float(Model(grouped).loss(p2, batch))
    assert abs(l1 - l2) < 1e-5, (l1, l2)


def test_grouping_falls_back_when_indivisible():
    from repro.models.transformer import segments_of
    cfg = REDUCED["qwen3-0.6b"].replace(n_layers=5, layers_per_step=2)
    segs = segments_of(cfg)
    assert sum(n * len(k) for n, k in segs) == 5
