import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import BloomFilter, DoubleHashBloomFilter, optimal_k
from repro.core.theory import bf_fpr


@given(st.integers(0, 2**32), st.integers(1, 6), st.integers(100, 5000))
@settings(max_examples=20, deadline=None)
def test_no_false_negatives(seed, k, n):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 1 << 63, n).astype(np.uint64)
    bf = BloomFilter(n * 10, k=k)
    bf.insert(keys)
    assert bf.query(keys).all()


def test_fpr_close_to_theory():
    rng = np.random.default_rng(0)
    n, b = 50_000, 10
    keys = rng.integers(0, 1 << 63, 2 * n).astype(np.uint64)
    pos, neg = keys[:n], keys[n:]
    k = optimal_k(b)
    bf = BloomFilter(n * b, k=k)
    bf.insert(pos)
    measured = bf.query(neg).mean()
    expected = bf_fpr(b, k)
    assert 0.5 * expected < measured < 2.0 * expected


def test_per_key_phi():
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 1 << 63, 100).astype(np.uint64)
    phi = rng.integers(0, 22, (100, 3))
    bf = BloomFilter(10_000, k=3)
    bf.insert(keys, phi=phi)
    assert bf.query(keys, phi=phi).all()
    # with different phi the same keys are (mostly) not found
    phi2 = (phi + 7) % 22
    assert bf.query(keys, phi=phi2).mean() < 0.5


def test_double_hash_variant():
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 1 << 63, 5000).astype(np.uint64)
    bf = DoubleHashBloomFilter(50_000, k=4)
    bf.insert(keys)
    assert bf.query(keys).all()
    other = rng.integers(0, 1 << 63, 5000).astype(np.uint64)
    assert bf.query(other).mean() < 0.2


def test_bit_vector_clear():
    bf = BloomFilter(1024, k=1)
    bf.bits.set_bits(np.asarray([5, 37, 1023]))
    assert bf.bits.count() == 3
    bf.bits.clear_bit(37)
    assert bf.bits.count() == 2
    assert bf.bits.test_bits(np.asarray([5, 37, 1023])).tolist() == [1, 0, 1]
