"""Property tests for the Xor/WBF kernel invariants (hypothesis; offline
containers get the deterministic fallback via tests/conftest.py):

* zero FNR on inserted keys — host, jnp ref, and Pallas kernel;
* fp_bits masking never produces fingerprint 0 (host and device mirrors
  agree bit-for-bit);
* `query_keys(artifact, costs=)` agrees with the live filter's
  `ks_for_costs` bucketing and query decisions.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import SpaceBudget, make_filter
from repro.core.wbf import WeightedBloomFilter, ks_for_costs
from repro.core.xor_filter import XorFilter, _FP_FAMILY, _fingerprint
from repro.kernels import query_keys

u64s = st.integers(min_value=0, max_value=(1 << 62) - 1)


def _np_keys(keys):
    return np.asarray(keys, np.uint64)


@settings(max_examples=8, deadline=None)
@given(st.lists(u64s, min_size=1, max_size=64),
       st.integers(min_value=2, max_value=16))
def test_xor_zero_fnr_host_ref_kernel(keys, fp_bits):
    keys = _np_keys(keys)
    f = XorFilter(keys, fingerprint_bits=fp_bits)
    assert f.query(keys).all(), "host FNR > 0"
    assert np.asarray(query_keys(f, keys, use_kernel=False)).all(), \
        "jnp ref FNR > 0"
    assert np.asarray(query_keys(f, keys, use_kernel=True,
                                 interpret=True)).all(), "kernel FNR > 0"


@settings(max_examples=8, deadline=None)
@given(st.lists(u64s, min_size=1, max_size=128),
       st.integers(min_value=1, max_value=32))
def test_xor_fingerprint_never_zero(keys, fp_bits):
    keys = _np_keys(keys)
    host_fp = _fingerprint(keys, fp_bits)
    assert (host_fp != 0).all(), "host fp_bits masking produced 0"
    # device mirror (the exact computation the ref and kernel share)
    import jax.numpy as jnp
    from repro.core.hashing import split_u64
    from repro.kernels import common
    lo, hi = split_u64(keys)
    dev_fp = common.hash_value(jnp.asarray(lo), jnp.asarray(hi),
                               jnp.asarray(_FP_FAMILY["c1"][3]),
                               jnp.asarray(_FP_FAMILY["c2"][3]),
                               jnp.asarray(_FP_FAMILY["mul"][3]))
    dev_fp = jnp.maximum(dev_fp & jnp.uint32((1 << fp_bits) - 1),
                         jnp.uint32(1))
    assert (np.asarray(dev_fp) != 0).all(), "device fp masking produced 0"
    np.testing.assert_array_equal(np.asarray(dev_fp), host_fp)


@settings(max_examples=8, deadline=None)
@given(st.lists(u64s, min_size=2, max_size=64),
       st.floats(min_value=0.0, max_value=2.0),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=4),
       st.booleans())
def test_wbf_zero_fnr_under_skewed_costs(keys, skew, k_bar, k_extra,
                                         use_kernel):
    keys = _np_keys(keys)
    rng = np.random.default_rng(0)
    costs = np.exp(skew * rng.standard_normal(len(keys)))
    wbf = WeightedBloomFilter(4096, k_bar=k_bar, k_max=k_bar + k_extra)
    wbf.insert(keys, costs)
    assert wbf.query(keys).all(), "host FNR > 0"
    # uncached fallback path (no costs at query time) stays zero-FNR
    assert np.asarray(query_keys(wbf, keys, use_kernel=use_kernel,
                                 interpret=True)).all(), "device FNR > 0"
    # supplying the insert-time costs recovers the exact k_e per key
    assert np.asarray(query_keys(wbf, keys, costs=costs,
                                 use_kernel=use_kernel,
                                 interpret=True)).all(), \
        "device FNR > 0 with costs="


@settings(max_examples=8, deadline=None)
@given(st.lists(st.floats(min_value=1e-3, max_value=1e3),
                min_size=1, max_size=64),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=4))
def test_wbf_ks_bucketing_bounds_and_parity(costs, k_bar, k_extra):
    k_max = k_bar + k_extra
    costs = np.asarray(costs, np.float64)
    ks = ks_for_costs(costs, k_bar, k_max)
    assert ((ks >= 1) & (ks <= k_max)).all(), "ks escaped [1, k_max]"
    # the live filter's query-side bucketing is the same shared function
    wbf = WeightedBloomFilter(2048, k_bar=k_bar, k_max=k_max)
    keys = np.arange(1, len(costs) + 1, dtype=np.uint64)
    np.testing.assert_array_equal(ks, wbf.query_ks(keys, costs))


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=16, max_value=200),
       st.floats(min_value=0.5, max_value=1.5))
def test_wbf_query_costs_agrees_with_live_filter(n, skew):
    rng = np.random.default_rng(n)
    pos = rng.choice(np.uint64(1) << np.uint64(62), 2 * n,
                     replace=False).astype(np.uint64)
    pos, neg = pos[:n], pos[n:]
    space = SpaceBudget.from_bits_per_key(10, n)
    f = make_filter("wbf", pos, space=space,
                    pos_costs=np.exp(skew * rng.standard_normal(n)))
    qcosts = np.exp(skew * rng.standard_normal(n))
    art = f.to_artifact()
    host = np.asarray(f.query(neg, qcosts))
    for uk in (False, True):
        dev = np.asarray(query_keys(art, neg, costs=qcosts, use_kernel=uk,
                                    interpret=True))
        np.testing.assert_array_equal(host, dev)
