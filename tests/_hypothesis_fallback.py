"""Minimal deterministic stand-in for `hypothesis`, used only when the
real package is not installed (e.g. an offline container).

Covers exactly the API surface this repo's property tests use:
``given``, ``settings(max_examples=, deadline=)``, and
``strategies.integers / floats / booleans / lists / sampled_from``.
Examples are drawn from a per-test seeded PRNG (seeded by the test name),
so runs are reproducible; the first example pins every strategy to its
lower bound and the second to its upper bound to keep the cheap edge-case
coverage real hypothesis would provide.

This is NOT a shrinking property-testing engine — install `hypothesis`
(declared in pyproject's test extra) to get the real thing; the conftest
prefers it automatically whenever it is importable.
"""
from __future__ import annotations

import functools
import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random, mode: str):
        return self._draw(rng, mode)


def integers(min_value: int, max_value: int) -> _Strategy:
    def draw(rng, mode):
        if mode == "lo":
            return min_value
        if mode == "hi":
            return max_value
        return rng.randint(min_value, max_value)

    return _Strategy(draw)


def floats(min_value: float, max_value: float) -> _Strategy:
    def draw(rng, mode):
        if mode == "lo":
            return float(min_value)
        if mode == "hi":
            return float(max_value)
        return rng.uniform(min_value, max_value)

    return _Strategy(draw)


def booleans() -> _Strategy:
    return _Strategy(lambda rng, mode: {"lo": False, "hi": True}.get(
        mode, rng.random() < 0.5))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)

    def draw(rng, mode):
        if mode == "lo":
            return seq[0]
        if mode == "hi":
            return seq[-1]
        return seq[rng.randrange(len(seq))]

    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int | None = None) -> _Strategy:
    def draw(rng, mode):
        hi = min_size + 8 if max_size is None else max_size
        if mode == "lo":
            n = min_size
        elif mode == "hi":
            n = hi
        else:
            n = rng.randint(min_size, hi)
        return [elements.draw(rng, mode) for _ in range(n)]

    return _Strategy(draw)


class strategies:
    """Namespace mirror so `from hypothesis import strategies as st` works."""
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    lists = staticmethod(lists)
    sampled_from = staticmethod(sampled_from)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn

    return deco


def given(*strats, **kw_strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            # read at call time so @settings works above OR below @given
            conf = (getattr(wrapper, "_fallback_settings", None)
                    or getattr(fn, "_fallback_settings", None)
                    or {"max_examples": 10})
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            n = conf["max_examples"]
            for i in range(n):
                mode = "lo" if i == 0 else ("hi" if i == 1 and n > 1
                                            else "rand")
                args = [s.draw(rng, mode) for s in strats]
                kwargs = {k: s.draw(rng, mode) for k, s in kw_strats.items()}
                try:
                    fn(*args, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (hypothesis-fallback, "
                        f"example {i}/{n}): args={args!r} kwargs={kwargs!r}"
                    ) from e

        # pytest follows __wrapped__ when introspecting the signature and
        # would demand fixtures named after the original parameters
        del wrapper.__wrapped__
        return wrapper

    return deco
