"""Unit + property tests for logical-axis sharding resolution."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.runtime import sharding as sh


def _mesh(shape, names):
    # AbstractMesh: spec resolution is pure metadata (works on 1 device);
    # jax >= 0.4.36 takes ((name, size), ...) pairs
    return AbstractMesh(tuple(zip(names, shape)))


def test_logical_to_spec_basics():
    rules = dict(sh.DEFAULT_RULES)
    spec = sh.logical_to_spec(("batch", "seq", "heads"), rules)
    assert spec == P(("pod", "data"), None, "model")


def test_duplicate_mesh_axis_dropped():
    rules = dict(sh.DEFAULT_RULES)
    # batch uses data; a second data-mapped axis must degrade to None
    rules["seq"] = "data"
    spec = sh.logical_to_spec(("batch", "seq"), rules)
    assert spec == P(("pod", "data"))


def test_divisibility_degradation():
    big = _mesh((2, 4), ("data", "model"))
    # kv_heads=2 cannot shard over model=4 -> replicated
    ns = sh.spec_for(big, sh.DEFAULT_RULES,
                     ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                     shape=(4, 8, 64, 2, 16))
    assert ns.spec == P(None, "data")
    # but 8 kv heads shard fine over 4
    ns2 = sh.spec_for(big, sh.DEFAULT_RULES,
                      ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                      shape=(4, 8, 64, 8, 16))
    assert ns2.spec == P(None, "data", None, "model")


def test_decode_rules_shard_kv_seq():
    big = _mesh((2, 4), ("data", "model"))
    ns = sh.spec_for(big, sh.DECODE_RULES,
                     ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                     shape=(4, 8, 64, 2, 16))
    assert ns.spec == P(None, "data", "model")


def test_missing_mesh_axis_dropped():
    single = _mesh((2, 2), ("data", "model"))  # no "pod"
    ns = sh.spec_for(single, sh.DEFAULT_RULES, ("batch",), shape=(8,))
    assert ns.spec == P("data")


@given(st.lists(st.sampled_from([None, "batch", "seq", "heads", "ffn",
                                 "vocab", "experts", "kv_seq", "d_model"]),
                min_size=1, max_size=5),
       st.lists(st.sampled_from([1, 2, 3, 4, 6, 8, 16]), min_size=1,
                max_size=5))
@settings(max_examples=50, deadline=None)
def test_spec_never_violates_divisibility(axes, dims):
    n = min(len(axes), len(dims))
    axes, dims = axes[:n], tuple(dims[:n])
    m = _mesh((2, 2), ("data", "model"))
    ns = sh.spec_for(m, sh.DEFAULT_RULES, axes, shape=dims)
    for i, part in enumerate(ns.spec):
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        extent = int(np.prod([m.shape[p] for p in parts]))
        assert dims[i] % extent == 0
    # no mesh axis twice
    used = [p for part in ns.spec if part is not None
            for p in (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))


def test_heads_divisible_helper():
    m = _mesh((2, 16), ("data", "model"))
    sh._ctx().append((m, dict(sh.DEFAULT_RULES)))
    try:
        assert sh.heads_divisible("heads", 32)
        assert not sh.heads_divisible("heads", 6)
        assert sh.heads_divisible("heads", 40) is False  # llama4: 40 % 16
    finally:
        sh._ctx().pop()
    assert sh.heads_divisible("heads", 7)  # no mesh -> permissive
