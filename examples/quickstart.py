"""Quickstart for the unified filter API: build any registered filter with
`make_filter`, see HABF beat a Bloom filter at equal memory, export a
typed pytree artifact, and run the same query through the device path.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import SpaceBudget, available_filters, make_filter, \
    weighted_fpr, zipf_costs
from repro.core.datasets import make_shalla
from repro.kernels import load_artifact, query_keys

# 1. keys: synthetic Shalla-like URL blacklist (paper §V-C)
ds = make_shalla(scale=0.01, seed=0)
print(f"dataset: {ds.n_pos} positive / {ds.n_neg} negative keys")
print(f"registry: {', '.join(available_filters())}")

# 2. skewed per-key costs (Zipf 1.0, paper §V-F)
costs = zipf_costs(ds.n_neg, skew=1.0, seed=1)

# 3. build HABF and a standard BF with the SAME space budget
space = SpaceBudget.from_bits_per_key(10, ds.n_pos)   # 10 bits/key
habf = make_filter("habf", ds.pos_u64, ds.neg_u64, costs, space=space,
                   seed=0)
bf = make_filter("bloom", ds.pos_u64, space=space)

print(f"zero FNR: {bool(habf.query(ds.pos_u64).all())}")
print(f"weighted FPR  HABF: {weighted_fpr(habf.query(ds.neg_u64), costs):.3e}")
print(f"weighted FPR  BF  : {weighted_fpr(bf.query(ds.neg_u64), costs):.3e}")
s = habf.summary()
print(f"TPJO: {s['n_optimized']}/{s['n_collision_total']} collision keys "
      f"optimized, {s['hx_inserted']} keys in HashExpressor")

# 4. the same two-round query on device (Pallas kernel, interpret on CPU):
#    to_artifact() gives a typed pytree — it jits, vmaps, device_puts, and
#    save/load round-trips through one npz for serving hot-swap.  Every
#    artifact type has a kernel path (bloom/habf/ngram/xor/wbf kernels;
#    adabf rides the wbf kernel, learned filters the bloom kernel), so
#    query/query_keys honor use_kernel=True for whatever you build here.
art = habf.to_artifact()
dev = np.asarray(query_keys(art, ds.neg_u64))
host = habf.query(ds.neg_u64)
assert (dev == host).all()
print(f"device kernel matches host query on {len(dev)} keys")

art.save("/tmp/habf_artifact.npz")
dev2 = np.asarray(query_keys(load_artifact("/tmp/habf_artifact.npz"),
                             ds.neg_u64))
assert (dev2 == host).all()
print("artifact npz round-trip matches too")

# 5. serving several filters per pod: a FilterBank registers named
#    artifacts, places each one mesh-aware (small tables replicated for
#    VMEM residency, 1MB+ words/table arrays sharded over `model`), and
#    serves them behind one entrypoint with per-filter telemetry (probe
#    counts, hit rate, estimated FP cost, kernel-vs-ref path).  See
#    examples/multi_filter_serve.py for the full serving demo with the
#    admission gate + n-gram blocklist fused into jitted decode steps.
from repro.runtime.filter_bank import FilterBank

bank = FilterBank()              # pass mesh= for sharded placement
bank.register("admission", habf)
bank.register("dedup", bf)
hits = bank.query_batch({"admission": ds.neg_u64, "dedup": ds.neg_u64},
                        costs=costs)
assert (np.asarray(hits["admission"]) == host).all()
print("FilterBank serves both filters behind one entrypoint:")
print(bank.summary())
# bank.swap("dedup", rebuilt_filter) is the double-buffered hot-swap
# publish point for background rebuilds.
