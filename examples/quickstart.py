"""Quickstart: build an HABF, see it beat a Bloom filter at equal memory,
and run the same query through the Pallas device kernel.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (HABF, BloomFilter, optimal_k, weighted_fpr,
                        zipf_costs)
from repro.core.datasets import make_shalla
from repro.kernels import habf_query_u64

# 1. keys: synthetic Shalla-like URL blacklist (paper §V-C)
ds = make_shalla(scale=0.01, seed=0)
print(f"dataset: {ds.n_pos} positive / {ds.n_neg} negative keys")

# 2. skewed per-key costs (Zipf 1.0, paper §V-F)
costs = zipf_costs(ds.n_neg, skew=1.0, seed=1)

# 3. build HABF and a standard BF with the SAME total memory
total_bytes = ds.n_pos * 10 // 8          # 10 bits/key
habf = HABF.build(ds.pos_u64, ds.neg_u64, costs, total_bytes=total_bytes,
                  k=3, seed=0)
bf = BloomFilter(total_bytes * 8, k=optimal_k(10))
bf.insert(ds.pos_u64)

print(f"zero FNR: {bool(habf.query(ds.pos_u64).all())}")
print(f"weighted FPR  HABF: {weighted_fpr(habf.query(ds.neg_u64), costs):.3e}")
print(f"weighted FPR  BF  : {weighted_fpr(bf.query(ds.neg_u64), costs):.3e}")
s = habf.summary()
print(f"TPJO: {s['n_optimized']}/{s['n_collision_total']} collision keys "
      f"optimized, {s['hx_inserted']} keys in HashExpressor")

# 4. the same two-round query on device (Pallas kernel, interpret on CPU)
dev = np.asarray(habf_query_u64(habf, ds.neg_u64))
host = habf.query(ds.neg_u64)
assert (dev == host).all()
print(f"device kernel matches host query on {len(dev)} keys")
