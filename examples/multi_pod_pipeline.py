"""Pipeline parallelism demo: GPipe stages over a (simulated) pod axis.

Runs a 4-stage pipeline of transformer-ish blocks over 8 host devices and
verifies the fill/drain schedule reproduces sequential execution exactly.

  PYTHONPATH=src python examples/multi_pod_pipeline.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.runtime.pipeline import gpipe

S, n_micro, mb, d = 4, 12, 2, 64
key = jax.random.PRNGKey(0)
k1, k2, k3 = jax.random.split(key, 3)
params = {
    "w1": jax.random.normal(k1, (S, d, 2 * d)) * 0.1,
    "w2": jax.random.normal(k2, (S, 2 * d, d)) * 0.1,
    "ln": jnp.ones((S, d)),
}


def apply_stage(p, h):
    hn = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6) * p["ln"]
    return h + jnp.tanh(hn @ p["w1"]) @ p["w2"]


x = jax.random.normal(k3, (n_micro, mb, d))
ref = x
for s in range(S):
    ref = apply_stage(jax.tree.map(lambda t: t[s], params), ref)

mesh = jax.make_mesh((4, 2), ("pipe", "data"))
y = gpipe(apply_stage, params, x, mesh, axis="pipe")
err = float(jnp.max(jnp.abs(y - ref)))
bubble = (S - 1) / (n_micro + S - 1)
print(f"4-stage GPipe over {mesh.devices.size} devices: max|err| = {err:.2e}")
print(f"schedule: {n_micro + S - 1} steps for {n_micro} microbatches "
      f"(bubble fraction {bubble:.0%})")
assert err < 1e-5
