"""Mini reproduction of the paper's headline comparison (Fig. 10/11 at
reduced scale): weighted FPR vs space for every filter in the unified
registry under uniform and Zipf(1.0) costs.  Full-scale sweeps:
benchmarks/run.py.

  PYTHONPATH=src python examples/filter_comparison.py
"""
import numpy as np

from repro.core import SpaceBudget, make_filter, weighted_fpr, zipf_costs
from repro.core.datasets import make_shalla

FILTERS = ("habf", "fhabf", "bloom", "xor", "wbf")

ds = make_shalla(scale=0.01, seed=0)
print(f"# dataset shalla-like scale=0.01: {ds.n_pos} pos / {ds.n_neg} neg")
print("skew,bits_per_key," + ",".join(FILTERS))

for skew in (0.0, 1.0):
    costs = zipf_costs(ds.n_neg, skew, seed=1)
    for bpk in (8, 10, 12, 14):
        space = SpaceBudget.from_bits_per_key(bpk, ds.n_pos)
        row = []
        for name in FILTERS:
            f = make_filter(name, ds.pos_u64, ds.neg_u64, costs,
                            space=space, seed=0)
            row.append(weighted_fpr(f.query(ds.neg_u64), costs))
        print(f"{skew},{bpk}," + ",".join(f"{v:.3e}" for v in row))
