"""Mini reproduction of the paper's headline comparison (Fig. 10/11 at
reduced scale): weighted FPR vs space for HABF / f-HABF / BF / Xor / WBF
under uniform and Zipf(1.0) costs.  Full-scale sweeps: benchmarks/run.py.

  PYTHONPATH=src python examples/filter_comparison.py
"""
import numpy as np

from repro.core import (HABF, BloomFilter, WeightedBloomFilter, optimal_k,
                        weighted_fpr, xor_filter_for_space, zipf_costs)
from repro.core.datasets import make_shalla

ds = make_shalla(scale=0.01, seed=0)
print(f"# dataset shalla-like scale=0.01: {ds.n_pos} pos / {ds.n_neg} neg")
print("skew,bits_per_key,habf,fhabf,bf,xor,wbf")

for skew in (0.0, 1.0):
    costs = zipf_costs(ds.n_neg, skew, seed=1)
    for bpk in (8, 10, 12, 14):
        total = ds.n_pos * bpk // 8
        habf = HABF.build(ds.pos_u64, ds.neg_u64, costs, total_bytes=total,
                          k=3, seed=0)
        fh = HABF.build(ds.pos_u64, ds.neg_u64, costs, total_bytes=total,
                        k=3, seed=0, fast=True)
        bf = BloomFilter(total * 8, k=optimal_k(bpk))
        bf.insert(ds.pos_u64)
        xf = xor_filter_for_space(ds.pos_u64, total)
        wbf = WeightedBloomFilter(total * 8, k_bar=optimal_k(bpk))
        wbf.build(ds.pos_u64, None)
        row = [weighted_fpr(f.query(ds.neg_u64), costs)
               for f in (habf, fh, bf, xf)]
        row.append(weighted_fpr(wbf.query(ds.neg_u64), costs))
        print(f"{skew},{bpk}," + ",".join(f"{v:.3e}" for v in row))
