"""Train a reduced LM end-to-end with the full substrate: HABF-dedup data
pipeline, AdamW + schedule, checkpointing + fault-tolerant supervisor.

  PYTHONPATH=src python examples/train_dedup.py
"""
import tempfile

from repro.launch.train import run

with tempfile.TemporaryDirectory() as ckpt_dir:
    out = run(arch="qwen3-0.6b", reduced=True, steps=60, batch=8, seq=64,
              lr=3e-3, ckpt_dir=ckpt_dir, save_every=20, dedup=True, seed=0)

print(f"loss: {out['losses'][0]:.3f} -> {out['final_loss']:.3f} "
      f"over {len(out['losses'])} steps")
print(f"dedup filter skipped {out['skipped_docs']} duplicate docs")
assert out["final_loss"] < out["losses"][0]
