"""End-to-end serving with the HABF admission gate + n-gram blocklist
(the paper-dictated driver: HABF is a serving-layer structure).

Batched requests hit a small LM; half ask for prefixes that are resident
in the (synthetic) KV-prefix cache — the HABF admission probe, fused into
the prefill step, admits exactly those (zero FNR) while keeping the
weighted cost of false admits far below a Bloom filter of the same size.

  PYTHONPATH=src python examples/serve_with_habf_cache.py
"""
from repro.launch.serve import run

out = run(arch="qwen3-0.6b", reduced=True, batch=8, prompt_len=48, gen=16)

fs = out["filter_stats"]
print(f"served {out['batch']} requests @ {out['tokens_per_s']:.1f} tok/s "
      f"(latency {out['latency_s']:.2f}s)")
print(f"admission: {out['admitted']}/{out['batch']} admitted "
      f"(batch is half cached / half missing prefixes)")
print(f"blocklist: {out['blocked_ngrams']} n-gram hits during decode")
print(f"filter quality at equal memory — HABF wFPR "
      f"{fs['habf_weighted_fpr']:.2e} vs BF {fs['bf_weighted_fpr']:.2e}; "
      f"zero FNR: {fs['zero_fnr']}")
assert out["admitted"] == out["batch"] // 2
