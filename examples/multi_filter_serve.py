"""Multi-filter serving: one FilterBank drives every filter a pod runs.

Four heterogeneous filters — very different memory/accuracy profiles —
served behind one dispatcher with per-filter telemetry:

  * ``admission``  HABF over KV-prefix fingerprints (cost-skewed, §V-F)
  * ``blocklist``  n-gram Bloom blocklist, fused into the decode step
  * ``dedup``      request-dedup Bloom over recent request fingerprints
  * ``cache``      Xor index of response-cache fingerprints

The admission gate and blocklist close over into the jitted serve steps
(`generate(..., bank=bank)`); dedup and cache are served out-of-loop via
`bank.query`.  The bank places every artifact mesh-aware (big tables
shard over `model`, small ones replicate) and `bank.swap` hot-publishes a
rebuilt filter without a restart.

  PYTHONPATH=src python examples/multi_filter_serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import SpaceBudget, make_filter, zipf_costs
from repro.core.hashing import fingerprint_bytes
from repro.kernels import build_blocklist
from repro.models.model import Model
from repro.runtime.filter_bank import FilterBank
from repro.runtime.serve_loop import generate

BATCH, PROMPT, GEN, SEED = 4, 32, 8, 0
rng = np.random.default_rng(SEED)

# ---- the pod's filter fleet ------------------------------------------------
bank = FilterBank()  # pass mesh=make_production_mesh() on a real pod

cached = fingerprint_bytes([f"prefix-cached-{i}" for i in range(4000)])
missing = fingerprint_bytes([f"prefix-miss-{i}" for i in range(4000)])
space = SpaceBudget.from_bits_per_key(10, len(cached))
bank.register("admission", make_filter(
    "habf", cached, missing, zipf_costs(len(missing), 1.5, SEED),
    space=space, seed=SEED))

cfg = get_config("qwen3-0.6b", reduced=True)
bank.register("blocklist", build_blocklist(
    rng.integers(0, cfg.vocab, (64, 4)).astype(np.int32), 1 << 14, k=3))

recent = fingerprint_bytes([f"req-{i}" for i in range(2000)])
bank.register("dedup", make_filter(
    "bloom", recent, space=SpaceBudget.from_bits_per_key(12, len(recent))))

responses = fingerprint_bytes([f"resp-{i}" for i in range(2000)])
bank.register("cache", make_filter(
    "xor", responses, space=SpaceBudget.from_bits_per_key(12,
                                                          len(responses))))
print(f"bank serves {len(bank.names())} filters: {', '.join(bank.names())}")

# ---- request admission path (out-of-loop filters) --------------------------
stream = np.concatenate([recent[:BATCH // 2],
                         fingerprint_bytes([f"new-{i}"
                                            for i in range(BATCH // 2)])])
dup = np.asarray(bank.query("dedup", stream))
hit = np.asarray(bank.query("cache", stream))
print(f"dedup: {int(dup.sum())}/{BATCH} duplicate requests dropped; "
      f"cache: {int(hit.sum())} response-cache hits")

# ---- in-loop gates: admission probe + fused blocklist ----------------------
model = Model(cfg)
params, _ = model.init(jax.random.PRNGKey(SEED))
mix = np.concatenate([cached[:BATCH // 2], missing[:BATCH - BATCH // 2]])
prompt = {
    "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (BATCH, PROMPT)),
                          jnp.int32),
    "prefix_lo": jnp.asarray(mix & 0xFFFFFFFF, jnp.uint32),
    "prefix_hi": jnp.asarray(mix >> np.uint64(32), jnp.uint32),
}
cache = model.init_cache(BATCH, PROMPT + GEN + 1)
toks, cache, rep = generate(model, params, prompt, cache, GEN, bank=bank)
print(f"generated {toks.shape}; admitted {int(rep['admit'].sum())}/{BATCH} "
      f"(half the batch asks for cached prefixes); "
      f"blocked n-grams {rep['blocked_ngrams']}")
assert rep["admit"][: BATCH // 2].all()          # zero FNR on cached half

# ---- hot-swap publish point (async-rebuild roadmap item) -------------------
rebuilt = make_filter("bloom", np.concatenate([recent, stream[2:]]),
                      space=SpaceBudget.from_bits_per_key(12,
                                                          len(recent) + 2))
bank.swap("dedup", rebuilt)
assert np.asarray(bank.query("dedup", stream)).all()  # new set is live
print(f"hot-swapped dedup to v{bank.telemetry('dedup')['version']} "
      "(old artifact stays valid for in-flight steps)")

print("\nper-filter serving telemetry:")
print(bank.summary())
