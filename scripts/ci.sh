#!/usr/bin/env bash
# One-command local/CI gate: deps + tier-1 tests + a fast interpret-mode
# kernel parity smoke over every kernel-backed filter.
#
#   bash scripts/ci.sh            # everything
#   bash scripts/ci.sh --no-install
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--no-install" ]]; then
    # Offline containers ship the deps pre-baked; tolerate a failed install
    # (tests fall back to the deterministic hypothesis shim in tests/).
    python -m pip install -e ".[test]" 2>/dev/null \
        || echo "ci.sh: pip install failed (offline?) — using preinstalled deps"
fi

echo "== deprecation-shim gate (removed surfaces must stay removed) =="
if grep -rn --include="*.py" "device_tables\|query_u64" src/; then
    echo "ci.sh: FAIL — deprecation-shim surface resurfaced in src/" >&2
    exit 1
fi
echo "  no shim surfaces in src/"

echo "== tier-1 tests =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q

echo "== kernel parity smoke (Pallas interpret vs jnp ref vs host) =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import warnings

# import repro inside the recording block so import-time shim warnings
# (module-level warn / __getattr__ shims) are caught too
with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")

    import numpy as np

    from repro.core import SpaceBudget, make_filter, zipf_costs
    from repro.kernels import query_keys

    rng = np.random.default_rng(0)
    keys = rng.choice(np.uint64(1) << np.uint64(62), 12_000,
                      replace=False).astype(np.uint64)
    pos, neg = keys[:6000], keys[6000:]
    space = SpaceBudget.from_bits_per_key(10, len(pos))
    probe = np.concatenate([pos[:2000], neg[:2000]])
    for name in ("habf", "fhabf", "bloom", "bloom-double", "xor", "wbf"):
        kw = {"pos_costs": zipf_costs(len(pos), 1.5, 9)} if name == "wbf" \
            else {}
        f = make_filter(name, pos, neg, zipf_costs(len(neg), 1.0, 1),
                        space=space, seed=0, **kw)
        host = np.asarray(f.query(probe))
        kern = np.asarray(query_keys(f, probe, use_kernel=True))
        ref = np.asarray(query_keys(f, probe, use_kernel=False))
        assert (host == kern).all() and (host == ref).all(), name
        assert f.query(pos).all(), f"{name}: FNR > 0"
        assert np.asarray(query_keys(f, pos, use_kernel=True)).all(), \
            f"{name}: device FNR > 0"
        print(f"  {name}: kernel==ref==host on {len(probe)} keys; zero FNR")

    # WBF query-side cost bucketing rides the same kernel
    f = make_filter("wbf", pos, space=space,
                    pos_costs=zipf_costs(len(pos), 1.0, 5))
    qcosts = zipf_costs(len(neg), 1.0, 6)
    host = np.asarray(f.query(neg, qcosts))
    kern = np.asarray(query_keys(f, neg, costs=qcosts, use_kernel=True))
    assert (host == kern).all(), "wbf costs= parity"
    print("  wbf costs= bucketing: kernel==host")

# the shims are really gone: no repro code path may emit DeprecationWarning.
# Match provenance positively: warnings attributed to the repro tree or to
# this script itself (stacklevel=2 shims would point here) are ours;
# third-party deprecations from jax/numpy internals are not.
ours = [w for w in caught if issubclass(w.category, DeprecationWarning)
        and ("/repro/" in (w.filename or "")
             or (w.filename or "").startswith("<"))]
assert not ours, "DeprecationWarning from repro.*: " + \
    "; ".join(f"{w.filename}:{w.lineno}: {w.message}" for w in ours)
print("kernel parity smoke OK (and no repro DeprecationWarnings)")
EOF

echo "== multi-filter serve smoke (FilterBank: bloom + habf + ngram) =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import numpy as np

from repro.core import SpaceBudget, make_filter, zipf_costs
from repro.kernels import build_blocklist
from repro.runtime.filter_bank import FilterBank

rng = np.random.default_rng(0)
keys = rng.choice(np.uint64(1) << np.uint64(62), 8000,
                  replace=False).astype(np.uint64)
pos, neg = keys[:4000], keys[4000:]
space = SpaceBudget.from_bits_per_key(10, len(pos))
bank = FilterBank()  # interpret-mode kernels on this CPU container
habf = make_filter("habf", pos, neg, zipf_costs(len(neg), 1.0, 1),
                   space=space, seed=0)
bloom = make_filter("bloom", pos, space=space)
bank.register("admission", habf)
bank.register("dedup", bloom)
bank.register("blocklist", build_blocklist(
    rng.integers(0, 1000, (32, 4)).astype(np.int32), 1 << 14, k=3))
probe = np.concatenate([pos[:1000], neg[:1000]])
for name, f in (("admission", habf), ("dedup", bloom)):
    assert (np.asarray(bank.query(name, probe)) == f.query(probe)).all(), name
    assert np.asarray(bank.query(name, pos)).all(), f"{name}: FNR > 0"
toks = np.asarray(bank.query("blocklist", rng.integers(0, 1000, (4, 64))))
assert toks.shape == (4, 64)
tel = bank.telemetry()
assert set(tel) == {"admission", "dedup", "blocklist"}
for name, t in tel.items():
    assert t["queries"] >= 1 and t["kernel_queries"] >= 1, (name, t)
    assert t["bytes"] > 0
# hot-swap publish point: the new artifact serves, the old stays valid
old = bank.swap("dedup", make_filter("bloom", neg, space=space))
assert np.asarray(bank.query("dedup", neg)).all()
assert bank.telemetry("dedup")["version"] == 2
print(bank.summary())
print("multi-filter serve smoke OK")
EOF
echo "ci.sh: all green"
