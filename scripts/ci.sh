#!/usr/bin/env bash
# One-command local/CI gate: deps + tier-1 tests + a fast interpret-mode
# kernel parity smoke.
#
#   bash scripts/ci.sh            # everything
#   bash scripts/ci.sh --no-install
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" != "--no-install" ]]; then
    # Offline containers ship the deps pre-baked; tolerate a failed install
    # (tests fall back to the deterministic hypothesis shim in tests/).
    python -m pip install -e ".[test]" 2>/dev/null \
        || echo "ci.sh: pip install failed (offline?) — using preinstalled deps"
fi

echo "== tier-1 tests =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q

echo "== kernel parity smoke (Pallas interpret vs jnp ref vs host) =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'EOF'
import numpy as np
from repro.core import SpaceBudget, make_filter, zipf_costs
from repro.kernels import query_keys

rng = np.random.default_rng(0)
keys = rng.choice(np.uint64(1) << np.uint64(62), 12_000,
                  replace=False).astype(np.uint64)
pos, neg = keys[:6000], keys[6000:]
space = SpaceBudget.from_bits_per_key(10, len(pos))
probe = np.concatenate([pos[:2000], neg[:2000]])
for name in ("habf", "fhabf", "bloom", "bloom-double"):
    f = make_filter(name, pos, neg, zipf_costs(len(neg), 1.0, 1),
                    space=space, seed=0)
    host = np.asarray(f.query(probe))
    kern = np.asarray(query_keys(f, probe, use_kernel=True))
    ref = np.asarray(query_keys(f, probe, use_kernel=False))
    assert (host == kern).all() and (host == ref).all(), name
    assert f.query(pos).all(), f"{name}: FNR > 0"
    print(f"  {name}: kernel==ref==host on {len(probe)} keys; zero FNR")
print("kernel parity smoke OK")
EOF
echo "ci.sh: all green"
